//! `lpgd` — the Layer-3 coordinator CLI.
//!
//! ```text
//! lpgd list [--registry D]              experiments, schemes, grids (and
//!                                       cached-cell counts when a result
//!                                       registry is given)
//! lpgd serve [opts]                     HTTP experiment service over a
//!                                       content-addressed result registry
//!     --registry D   registry directory (required; created if missing)
//!     --addr A:P     bind address (default 127.0.0.1:7878; port 0 = any)
//!     --threads N    HTTP worker threads (default 4)
//!     --queue N      max in-flight cells before 429 (default 256)
//!     --jobs N       scheduler threads per request (default 0 = all cores)
//! lpgd reproduce <id|all> [opts]        regenerate a paper table/figure
//!     --seeds N      (default 5; paper uses 20)
//!     --jobs N       worker threads (default 0 = all cores; results are
//!                    bit-identical for every N — see docs/architecture.md)
//!     --out-dir D    (default results/)
//!     --quick        smoke-scale profile
//!     --side N --mlr-train N --mlr-epochs N ... (see ExpCtx)
//!     --journal P    append-only cell checkpoint file; --resume skips
//!                    cells already journaled under the same config
//!     --registry D   content-addressed result store: cells already in it
//!                    are served instead of recomputed, fresh cells are
//!                    written back (shared with `lpgd serve`; docs/service.md)
//!     --max-retries N --fault-policy fail-fast|skip-cell|degrade
//!     --escape X     terminate a run early once its loss exceeds X or
//!                    goes non-finite (see docs/robustness.md)
//!     --lanes N      run seed repetitions N at a time as interleaved
//!                    lane batches (execution knob: results and journals
//!                    are bit-identical at every width)
//!     --simd auto|avx2|scalar   pin the kernel backend (default: runtime
//!                    detection; see docs/performance.md)
//! lpgd train <mlr|nn> [opts]            one training run with any schemes
//!     --backend binary8 | fixed:Q3.8   number grid (--fmt is a legacy alias)
//!     --t 0.5 --epochs 50 --seed 0
//!     --scheme sr_eps:0.2    any registered scheme, all three steps
//!     --s8a sr --s8b sr --s8c signed:0.1   per-step overrides
//!     --policy policy:weights=sr_eps:0.4@bf16,m=rn@fp32   the full
//!                    per-tensor policy grammar (conflicts with --scheme
//!                    and the --s8* overrides)
//!     --optimizer gd | momentum:0.9 | nesterov:0.9 | adam:0.9:0.999:1e-8
//!     --lr-decay const | inv:0.1 | step:0.5:100
//!     --sr-bits N    few-random-bits knob for the stochastic kernels
//! lpgd round <value> [opts]             inspect rounding of one value
//!     --fmt binary8 --mode sr_eps:0.25 --samples 10000
//! lpgd goldens <extract|check> [opts]   golden-figure replication harness
//!     --dir D        goldens directory (default goldens/)
//!     --report P     write the JSON validation index to P
//!     --require      fail on missing goldens instead of bootstrapping
//!     --stream-change  CLT bands for stochastic columns (docs/testing.md)
//! lpgd pjrt-info                        PJRT platform + artifact check
//! lpgd --help                           usage + the registered schemes
//! ```
//!
//! Scheme specs resolve through the open
//! [`SchemeRegistry`](lpgd::fp::SchemeRegistry); unknown `--options` are
//! rejected with an error instead of being silently ignored.

use std::sync::Arc;

use anyhow::{bail, Result};
use lpgd::coordinator::experiments::{run_experiment, ExpCtx};
use lpgd::coordinator::{goldens, FaultPolicy, Journal};
use lpgd::data::load_or_synth;
use lpgd::fp::{
    set_backend, Grid, NumberGrid, Rng, RoundPlan, Scheme, SchemeRegistry, SimdChoice,
    DEFAULT_SR_BITS,
};
use lpgd::gd::{GdConfig, PolicyMap, RunBuilder};
use lpgd::problems::{Mlr, TwoLayerNn};
use lpgd::registry::ResultStore;
use lpgd::serve::{Catalog, ExperimentService, Server};
use lpgd::util::cli::Args;
use lpgd::util::table::sparkline;

/// `--key value` options shared by every command running the coordinator.
const CTX_OPTS: &[&str] = &[
    "seeds", "jobs", "out-dir", "side", "mlr-train", "mlr-test", "nn-train", "nn-test",
    "mlr-epochs", "nn-epochs", "quad-steps", "quad-n", "mnist-dir", "journal", "resume",
    "max-retries", "fault-policy", "escape", "lanes", "simd", "registry",
];

/// Open (or create) the content-addressed result registry at `dir`.
fn open_registry(dir: &str) -> Result<ResultStore> {
    ResultStore::open(std::path::Path::new(dir))
        .map_err(|e| anyhow::anyhow!("cannot open registry '{dir}': {e}"))
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn ctx_from_args(a: &Args) -> Result<ExpCtx> {
    let mut ctx = if a.has_flag("quick") { ExpCtx::quick() } else { ExpCtx::default() };
    ctx.seeds = a.get_usize("seeds", ctx.seeds);
    ctx.jobs = a.get_usize("jobs", ctx.jobs);
    ctx.out_dir = a.get("out-dir").unwrap_or(&ctx.out_dir).to_string();
    ctx.side = a.get_usize("side", ctx.side);
    ctx.mlr_train = a.get_usize("mlr-train", ctx.mlr_train);
    ctx.mlr_test = a.get_usize("mlr-test", ctx.mlr_test);
    ctx.nn_train = a.get_usize("nn-train", ctx.nn_train);
    ctx.nn_test = a.get_usize("nn-test", ctx.nn_test);
    ctx.mlr_epochs = a.get_usize("mlr-epochs", ctx.mlr_epochs);
    ctx.nn_epochs = a.get_usize("nn-epochs", ctx.nn_epochs);
    ctx.quad_steps = a.get_usize("quad-steps", ctx.quad_steps);
    ctx.quad_n = a.get_usize("quad-n", ctx.quad_n);
    ctx.mnist_dir = a.get("mnist-dir").map(String::from);
    ctx.max_retries = a.get_usize("max-retries", ctx.max_retries as usize) as u32;
    if let Some(p) = a.get("fault-policy") {
        ctx.fault_policy = FaultPolicy::parse(p).ok_or_else(|| {
            anyhow::anyhow!("unknown --fault-policy '{p}' (fail-fast | skip-cell | degrade)")
        })?;
    }
    if let Some(e) = a.get("escape") {
        let thr: f64 =
            e.parse().map_err(|_| anyhow::anyhow!("--escape takes a number, got '{e}'"))?;
        ctx.escape = Some(thr);
    }
    if let Some(l) = a.get("lanes") {
        let lanes: usize = l
            .parse()
            .map_err(|_| anyhow::anyhow!("--lanes takes a positive integer, got '{l}'"))?;
        if lanes == 0 {
            bail!("--lanes must be at least 1 (lane width, not a disable switch)");
        }
        ctx.lanes = lanes;
    }
    if let Some(s) = a.get("simd") {
        let choice = SimdChoice::parse(s).map_err(|e| anyhow::anyhow!("--simd: {e}"))?;
        set_backend(choice);
    }
    if let Some(dir) = a.get("registry") {
        ctx.registry = Some(Arc::new(open_registry(dir)?));
    }
    // The journal digest covers every cell-shaping knob, so it must be
    // computed after all of them (escape included) are in place.
    if let Some(path) = a.get("journal") {
        let resume = a.has_flag("resume");
        let journal = Journal::open(std::path::Path::new(path), resume, ctx.config_digest())
            .map_err(|e| anyhow::anyhow!("cannot open journal '{path}': {e}"))?;
        if resume {
            eprintln!(
                "journal: {} completed cell(s) loaded from {path}",
                journal.resumed_cells()
            );
        }
        ctx.journal = Some(Arc::new(journal));
    } else if a.has_flag("resume") {
        bail!("--resume requires --journal PATH");
    }
    Ok(ctx)
}

/// Resolve `--key` through the scheme registry, or keep `default`.
fn scheme_arg(a: &Args, key: &str, default: Scheme) -> Result<Scheme> {
    match a.get(key) {
        None => Ok(default),
        Some(s) => Ok(SchemeRegistry::lookup(s)?),
    }
}

/// Reject argv carrying options no command reads (silent ignores used to
/// swallow typos like `--sceme`).
fn reject_unknown(a: &Args, known: &[&str]) -> Result<()> {
    let bad = a.unknown_keys(known);
    if !bad.is_empty() {
        bail!("unknown option(s): --{} (run `lpgd --help` for usage)", bad.join(", --"));
    }
    let missing = a.missing_values(known);
    if !missing.is_empty() {
        bail!(
            "option(s) missing a value: --{} (run `lpgd --help` for usage)",
            missing.join(", --")
        );
    }
    Ok(())
}

fn print_help() {
    println!("lpgd — low-precision GD with stochastic rounding (paper reproduction)");
    println!();
    println!("commands:");
    println!("  list [--registry D]         list experiments, schemes, grids (and cached-cell counts)");
    println!("  serve [opts]                HTTP experiment service over a content-addressed result");
    println!("                              registry: --registry D (required), --addr A:P, --threads N,");
    println!("                              --queue N, --jobs N (docs/service.md)");
    println!("  reproduce <id|all> [opts]   regenerate a paper table/figure (--seeds, --jobs, --quick, --out-dir, ...)");
    println!("                              fault tolerance: --journal PATH [--resume], --max-retries N,");
    println!("                              --fault-policy fail-fast|skip-cell|degrade, --escape X (docs/robustness.md)");
    println!("                              performance: --lanes N (multi-seed lane batches), --simd auto|avx2|scalar");
    println!("                              (both execution-only: bit-identical results; docs/performance.md)");
    println!("                              caching: --registry D serves already-computed cells and writes");
    println!("                              fresh ones back (shared with `lpgd serve`; docs/service.md)");
    println!("  train <mlr|nn> [opts]       one training run (--backend/--fmt, --t, --epochs, --seed, --scheme, --s8a/--s8b/--s8c, --sr-bits)");
    println!("                              optimizer zoo: --optimizer gd|momentum:b|nesterov:b|adam:b1:b2:eps,");
    println!("                              --lr-decay const|inv:r|step:g:p, --policy policy:weights=rn@binary64,m=sr@bf16");
    println!("  round <value> [opts]        inspect rounding of one value (--fmt, --mode, --samples, --seed)");
    println!("  goldens <extract|check>     golden-figure harness (--dir, --report, --require, --stream-change)");
    println!("  pjrt-info [--artifacts D]   PJRT platform + artifact check");
    println!();
    println!("registered rounding schemes (--scheme / --s8a / --s8b / --s8c / --mode):");
    for (name, aliases, summary) in SchemeRegistry::entries() {
        let alias = if aliases.is_empty() { String::new() } else { format!(" (aliases: {aliases})") };
        println!("  {name:<22} {summary}{alias}");
    }
    println!();
    println!("number backends (--backend, or legacy --fmt; both accept every spec):");
    println!("  float formats: binary8, bfloat16, binary16, binary32, binary64");
    println!("  fixed-point:   fixed:Qm.n / qm.n (signed), fixed:uQm.n / uqm.n (unsigned)");
    println!("                 e.g. --backend fixed:Q3.8  (delta=2^-8, range [-8, 8); docs/fixed-point.md)");
    println!("see README.md and docs/api.md for the library front door (RunBuilder)");
}

fn run() -> Result<()> {
    let a = Args::from_env();
    let cmd = a.positional.first().map(|s| s.as_str()).unwrap_or("help");
    if a.has_flag("help") || cmd == "help" {
        print_help();
        return Ok(());
    }
    match cmd {
        "list" => {
            reject_unknown(&a, &["registry"])?;
            let store = a.get("registry").map(open_registry).transpose()?;
            print!("{}", Catalog::gather(store.as_ref()).render_text());
            println!("\nusage: lpgd reproduce <id|all> [--seeds N] [--jobs N] [--quick] [--out-dir D]");
        }
        "serve" => {
            reject_unknown(&a, &["addr", "registry", "threads", "queue", "jobs"])?;
            let dir = a
                .get("registry")
                .ok_or_else(|| anyhow::anyhow!("serve requires --registry DIR (see docs/service.md)"))?;
            let store = Arc::new(open_registry(dir)?);
            println!("registry: {} cached cell(s) in {dir}", store.len());
            let service = Arc::new(ExperimentService::new(
                store,
                a.get_usize("queue", 256),
                a.get_usize("jobs", 0),
            ));
            let addr = a.get("addr").unwrap_or("127.0.0.1:7878");
            let server = Server::bind(addr, service)
                .map_err(|e| anyhow::anyhow!("cannot bind '{addr}': {e}"))?;
            // Tests and scripts parse this line for the ephemeral port.
            println!("listening on http://{}", server.local_addr()?);
            server.run(a.get_usize("threads", 4))?;
        }
        "reproduce" => {
            reject_unknown(&a, CTX_OPTS)?;
            let id = a.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            let ctx = ctx_from_args(&a)?;
            let jobs = if ctx.jobs == 0 { "auto".to_string() } else { ctx.jobs.to_string() };
            let t0 = std::time::Instant::now();
            let tables = run_experiment(id, &ctx)?;
            for t in &tables {
                println!("{}", t.to_text());
            }
            println!(
                "wrote {} CSV file(s) to {}/ in {:.1}s (--jobs {jobs})",
                tables.len(),
                ctx.out_dir,
                t0.elapsed().as_secs_f64()
            );
        }
        "train" => {
            let mut known = CTX_OPTS.to_vec();
            known.extend([
                "backend", "fmt", "t", "epochs", "seed", "scheme", "s8a", "s8b", "s8c", "sr-bits",
                "policy", "optimizer", "lr-decay",
            ]);
            reject_unknown(&a, &known)?;
            let which = a.positional.get(1).map(|s| s.as_str()).unwrap_or("mlr");
            let ctx = ctx_from_args(&a)?;
            // --policy is the whole per-tensor grammar; otherwise --scheme
            // sets all three steps and --s8a/--s8b/--s8c override.
            let policy = match a.get("policy") {
                Some(spec) => {
                    for k in ["scheme", "s8a", "s8b", "s8c"] {
                        if a.get(k).is_some() {
                            bail!("--policy sets the whole rounding policy; it conflicts with --{k}");
                        }
                    }
                    PolicyMap::parse(spec)?
                }
                None => {
                    let base = scheme_arg(&a, "scheme", Scheme::sr())?;
                    PolicyMap::sites(
                        scheme_arg(&a, "s8a", base)?,
                        scheme_arg(&a, "s8b", base)?,
                        scheme_arg(&a, "s8c", base)?,
                    )
                }
            };
            let optimizer = a.get("optimizer").unwrap_or("gd");
            let lr_decay = a.get("lr-decay").unwrap_or("const");
            // --backend is the grid spec (float name or fixed:Qm.n);
            // --fmt is the legacy spelling, kept as an alias.
            let fmt = a.get("backend").or_else(|| a.get("fmt")).unwrap_or("binary8");
            let seed = a.get_u64("seed", 0);
            let sr_bits = a.get_usize("sr-bits", DEFAULT_SR_BITS as usize) as u32;
            match which {
                "mlr" => {
                    let splits = load_or_synth(
                        ctx.mnist_dir.as_deref(),
                        ctx.mlr_train,
                        ctx.mlr_test,
                        ctx.side,
                        42,
                    );
                    let p = Mlr::new(splits.train, 10);
                    let t_step = a.get_f64("t", 0.5);
                    let epochs = a.get_usize("epochs", ctx.mlr_epochs);
                    let mut session = RunBuilder::new(&p)
                        .format_name(fmt)
                        .policy(policy)
                        .optimizer_name(optimizer)
                        .lr_name(lr_decay)
                        .stepsize(t_step)
                        .steps(epochs)
                        .seed(seed)
                        .sr_bits(sr_bits)
                        .build()?;
                    let metric = |x: &[f64]| p.test_error(x, &splits.test);
                    let tr = session.run(Some(&metric));
                    print_training("MLR", session.config(), &tr.metric_series());
                }
                "nn" => {
                    let splits = load_or_synth(
                        ctx.mnist_dir.as_deref(),
                        ctx.nn_train * 5,
                        ctx.nn_test * 5,
                        ctx.side,
                        77,
                    );
                    let train = splits.train.filter_classes(&[3, 8]);
                    let test = splits.test.filter_classes(&[3, 8]);
                    let p = TwoLayerNn::new(train, 100);
                    let t_step = a.get_f64("t", 0.09375);
                    let epochs = a.get_usize("epochs", ctx.nn_epochs);
                    let x0 = p.init_params(seed);
                    let mut session = RunBuilder::new(&p)
                        .format_name(fmt)
                        .policy(policy)
                        .optimizer_name(optimizer)
                        .lr_name(lr_decay)
                        .stepsize(t_step)
                        .steps(epochs)
                        .seed(seed)
                        .sr_bits(sr_bits)
                        .start(&x0)
                        .build()?;
                    let metric = |x: &[f64]| p.test_error(x, &test);
                    let tr = session.run(Some(&metric));
                    print_training("NN(3v8)", session.config(), &tr.metric_series());
                }
                other => bail!("unknown model '{other}' (mlr|nn)"),
            }
        }
        "round" => {
            reject_unknown(&a, &["backend", "fmt", "mode", "samples", "seed"])?;
            let val: f64 = a
                .positional
                .get(1)
                .ok_or_else(|| anyhow::anyhow!("usage: lpgd round <value>"))?
                .parse()?;
            let spec = a.get("backend").or_else(|| a.get("fmt")).unwrap_or("binary8");
            let fmt = Grid::parse(spec)
                .ok_or_else(|| anyhow::anyhow!("unknown --backend/--fmt '{spec}' (float format name or fixed:Qm.n)"))?;
            let scheme = SchemeRegistry::lookup(a.get("mode").unwrap_or("sr"))?;
            let samples = a.get_usize("samples", 10000);
            let (lo, hi) = fmt.floor_ceil(val);
            match fmt {
                Grid::Float(f) => println!(
                    "format {}  u={}  neighbors: [{lo}, {hi}]",
                    f.name(),
                    f.unit_roundoff()
                ),
                Grid::Fixed(f) => println!(
                    "grid {}  delta={}  range [{}, {}]  neighbors: [{lo}, {hi}]",
                    fmt.label(),
                    f.delta(),
                    fmt.min_value(),
                    fmt.max_value()
                ),
            }
            let plan = RoundPlan::new(fmt);
            let mut rng = Rng::new(a.get_u64("seed", 0));
            let mut mean = 0.0;
            let mut n_up = 0usize;
            for _ in 0..samples {
                let y = plan.round_scheme(scheme, val, &mut rng);
                mean += y;
                if y == hi && hi != lo {
                    n_up += 1;
                }
            }
            mean /= samples as f64;
            println!(
                "{}({val}) over {samples} samples: mean={mean}  bias={:+.3e}  P(up)={:.4}",
                scheme.label(),
                mean - val,
                n_up as f64 / samples as f64
            );
            println!("closed-form E[fl(x)]={}", scheme.expected_round(&fmt, val, val));
        }
        "goldens" => {
            reject_unknown(&a, &["dir", "report"])?;
            let action = a.positional.get(1).map(|s| s.as_str()).unwrap_or("check");
            let dir = std::path::PathBuf::from(a.get("dir").unwrap_or("goldens"));
            let ctx = goldens::golden_ctx();
            match action {
                "extract" => {
                    let written = goldens::extract(&dir, &ctx)?;
                    for p in &written {
                        println!("wrote {}", p.display());
                    }
                    println!(
                        "extracted {} golden artifact(s) to {}/ — commit them",
                        written.len(),
                        dir.display()
                    );
                }
                "check" => {
                    let opts = goldens::CheckOpts {
                        require: a.has_flag("require"),
                        stream_change: a.has_flag("stream-change"),
                    };
                    let report = goldens::check(&dir, &ctx, &opts)?;
                    print!("{}", report.to_text());
                    if let Some(p) = a.get("report") {
                        report.write_json(std::path::Path::new(p))?;
                        println!("validation index written to {p}");
                    }
                    goldens::ensure_passed(&report)?;
                }
                other => bail!("unknown goldens action '{other}' (extract|check)"),
            }
        }
        "pjrt-info" => {
            reject_unknown(&a, &["artifacts"])?;
            let dir = a.get("artifacts").unwrap_or("artifacts");
            let mut rt = lpgd::runtime::Runtime::cpu(dir)?;
            println!("platform: {}", rt.platform());
            for spec in [
                lpgd::runtime::QUANTIZE_SPEC,
                lpgd::runtime::MLR_SPEC,
                lpgd::runtime::NN_SPEC,
            ] {
                match rt.load(spec.file) {
                    Ok(e) => println!("  {} .. compiled OK ({} params)", e.name, spec.params),
                    Err(err) => println!("  {} .. FAILED: {err}", spec.file),
                }
            }
        }
        other => bail!("unknown command '{other}' (run `lpgd --help` for usage)"),
    }
    Ok(())
}

fn print_training(name: &str, cfg: &GdConfig, err: &[f64]) {
    println!(
        "{name} backend={} {} opt={} lr={} t={}: final test error {:.4}",
        cfg.grid.label(),
        cfg.schemes.label(),
        cfg.optimizer.canon(),
        cfg.lr.canon(),
        cfg.t,
        err.last().unwrap_or(&f64::NAN)
    );
    println!("test-error curve: {}", sparkline(err, 60));
}
