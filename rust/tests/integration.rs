//! Cross-module integration tests: the full experiment pipeline, the paper's
//! qualitative "shape" claims at smoke scale, and engine determinism.

use lpgd::coordinator::experiments::{run_experiment, ExpCtx};
use lpgd::data::load_or_synth;
use lpgd::fp::{FpFormat, Rounding, Scheme};
use lpgd::gd::engine::{GdConfig, GdEngine, PolicyMap};
use lpgd::problems::{Mlr, Problem, Quadratic};

fn quick_ctx(tag: &str) -> ExpCtx {
    let mut ctx = ExpCtx::quick();
    ctx.out_dir = std::env::temp_dir()
        .join(format!("lpgd_itest_{tag}"))
        .to_string_lossy()
        .into_owned();
    ctx
}

#[test]
fn all_experiments_run_and_write_csvs() {
    let ctx = quick_ctx("all");
    let tables = run_experiment("all", &ctx).expect("pipeline failed");
    assert_eq!(
        tables.len(),
        19,
        "12 paper artifacts + the fig4a-acc ablation + the plfp1-3 fixed-point family \
         + the opt1-3 optimizer-zoo family"
    );
    for t in &tables {
        let p = std::path::Path::new(&ctx.out_dir).join(format!("{}.csv", t.id));
        assert!(p.exists(), "missing {}", p.display());
        assert!(!t.rows.is_empty(), "{} produced no rows", t.id);
    }
}

/// The sharded scheduler's acceptance guarantee: running an experiment
/// through the worker pool produces *bit-identical* CSVs at `--jobs 1` and
/// `--jobs 8`, for the quadratic (expectation over seeds) and learning
/// (flattened config × seed grid) fan-out paths — and for the fixed-point
/// `plfp1` family (the PR-4 acceptance criterion:
/// `lpgd reproduce plfp1 --jobs 8` ≡ `--jobs 1`) — and for the
/// optimizer-zoo family `opt1`–`opt3` (stateful optimizers and per-tensor
/// policy bindings must not perturb the scheduler's determinism).
#[test]
fn experiments_are_bit_identical_across_job_counts() {
    for id in ["fig3a", "fig4b", "plfp1", "plfp3", "opt1", "opt2", "opt3"] {
        let mut c1 = quick_ctx(&format!("{id}_jobs1"));
        c1.jobs = 1;
        let mut c8 = quick_ctx(&format!("{id}_jobs8"));
        c8.jobs = 8;
        let t1 = run_experiment(id, &c1).expect("serial run failed");
        let t8 = run_experiment(id, &c8).expect("parallel run failed");
        assert_eq!(t1.len(), t8.len());
        for (a, b) in t1.iter().zip(&t8) {
            assert_eq!(a.to_csv(), b.to_csv(), "{id}: jobs=1 vs jobs=8 diverged");
            assert_eq!(a.notes, b.notes, "{id}: notes diverged across job counts");
        }
    }
}

#[test]
fn engine_is_deterministic_per_seed() {
    // Use a stepsize large enough that SR's randomness is actually exercised
    // (Setting I's paper stepsize t=1e-5 freezes every coordinate at this
    // scale, making all seeds trivially identical).
    let (p, x0, _) = Quadratic::setting1(50);
    let t = 0.3;
    let mk = |seed| {
        let mut cfg = GdConfig::new(FpFormat::BFLOAT16, Rounding::Sr, t, 40);
        cfg.seed = seed;
        let mut e = GdEngine::new(cfg, &p, &x0);
        let tr = e.run(None);
        (tr.objective_series(), e.x)
    };
    let (f1, x1) = mk(7);
    let (f2, x2) = mk(7);
    let (f3, x3) = mk(8);
    assert_eq!(f1, f2);
    assert_eq!(x1, x2);
    assert!(f1 != f3 || x1 != x3, "different seeds should differ");
}

/// The paper's core qualitative claims at smoke scale, across the whole
/// stack (data -> problem -> engine -> schemes):
/// RN stagnates above the optimum; SR converges; signed-SReps converges at
/// least as fast as SR in cumulative objective.
#[test]
fn paper_shape_claims_hold_end_to_end() {
    let splits = load_or_synth(None, 300, 100, 8, 1);
    let mlr = Mlr::new(splits.train, 10);
    let x0 = vec![0.0; mlr.dim()];
    let epochs = 15;

    let run = |schemes: PolicyMap, fmt: FpFormat, seed: u64| -> Vec<f64> {
        let mut cfg = GdConfig::new(fmt, schemes, 0.5, epochs);
        cfg.seed = seed;
        let mut e = GdEngine::new(cfg, &mlr, &x0);
        let metric = |x: &[f64]| mlr.test_error(x, &splits.test);
        e.run(Some(&metric)).metric_series()
    };

    let sr = Scheme::sr();
    let baseline = run(PolicyMap::uniform(Scheme::rn()), FpFormat::BINARY32, 0);
    let rn8 = run(PolicyMap::sites(Scheme::rn(), Scheme::rn(), sr), FpFormat::BINARY8, 0);
    let sr8 = run(PolicyMap::uniform(sr), FpFormat::BINARY8, 1);
    let sg8 = run(
        PolicyMap::sites(sr, sr, Scheme::signed_sr_eps(0.1)),
        FpFormat::BINARY8,
        1,
    );

    let last = |v: &Vec<f64>| *v.last().unwrap();
    // The baseline learns.
    assert!(last(&baseline) < 0.6, "baseline error {}", last(&baseline));
    // SR at binary8 is competitive with the baseline (within 0.25 abs).
    assert!(last(&sr8) < last(&baseline) + 0.25, "sr={} base={}", last(&sr8), last(&baseline));
    // signed-SReps is not slower than SR in final error (paper: faster).
    assert!(last(&sg8) <= last(&sr8) + 0.05, "signed={} sr={}", last(&sg8), last(&sr8));
    // RN at binary8 must not beat the baseline by more than noise — at this
    // smoke scale RN has not fully stagnated yet (that claim is asserted at
    // full scale by `lpgd reproduce fig4a`; see EXPERIMENTS.md), but it must
    // already trail the stochastic schemes' trend.
    assert!(last(&rn8) >= last(&baseline) - 0.1, "rn={} base={}", last(&rn8), last(&baseline));
}

#[test]
fn tau_threshold_is_necessary_and_sufficient_on_fig2() {
    // On the scalar Figure-2 problem, once tau_k <= u/2 and the lsb is even,
    // the very next RN step must not move — and conversely while tau > u/2
    // the iterate must move.
    use lpgd::gd::stagnation::tau_k;
    let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
    let fmt = FpFormat::BINARY8;
    let mut cfg = GdConfig::new(fmt, Rounding::RoundNearestEven, 0.05, 1);
    cfg.seed = 0;
    let mut e = GdEngine::new(cfg, &p, &[1.0]);
    for _ in 0..40 {
        let mut g = vec![0.0];
        p.gradient_exact(&e.x, &mut g);
        // chop-style (8a): in binary8 the stored gradient.
        let mut rng = lpgd::fp::Rng::new(0);
        g[0] = lpgd::fp::round(&fmt, Rounding::RoundNearestEven, g[0], &mut rng);
        let rep = tau_k(&fmt, &e.x, &g, 0.05);
        let x_before = e.x[0];
        let moved = e.step();
        if rep.below_threshold && rep.lsb_even {
            assert!(!moved, "tau={} <= u/2 but iterate moved from {x_before}", rep.tau);
        }
        if !rep.below_threshold {
            assert!(moved, "tau={} > u/2 but iterate stuck at {x_before}", rep.tau);
        }
    }
}

#[test]
fn dataset_to_problem_wiring() {
    // filter_classes -> NN problem -> dims consistent; MLR dims consistent.
    let splits = load_or_synth(None, 200, 50, 8, 3);
    assert_eq!(splits.train.n_features, 64);
    let mlr = Mlr::new(splits.train.clone(), 10);
    assert_eq!(mlr.dim(), 10 * 65);
    let bin = splits.train.filter_classes(&[3, 8]);
    assert!(bin.len() > 0 && bin.n_classes() == 2);
    let nn = lpgd::problems::TwoLayerNn::new(bin, 7);
    assert_eq!(nn.dim(), 7 * 66 + 1);
}

#[test]
fn unknown_ids_and_empty_dirs_fail_cleanly() {
    let ctx = quick_ctx("err");
    assert!(run_experiment("fig99", &ctx).is_err());
    assert!(lpgd::data::idx::load_mnist("/nope").is_err());
}

mod fault_tolerance {
    use super::*;
    use lpgd::coordinator::{FaultInjector, FaultPolicy, Journal};
    use std::sync::Arc;

    fn journal_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("lpgd_itest_journal_{}_{tag}.jsonl", std::process::id()))
    }

    /// PR acceptance: a sweep interrupted mid-flight (simulated kill -9:
    /// journal truncated to two intact lines plus a torn third) resumes
    /// from its journal and the merged CSV is byte-identical to an
    /// uninterrupted run.
    #[test]
    fn killed_sweep_resumes_to_a_byte_identical_csv() {
        let reference = run_experiment("plfp1", &quick_ctx("res_ref")).unwrap();
        let path = journal_path("resume");
        let _ = std::fs::remove_file(&path);

        let mut c1 = quick_ctx("res_a");
        c1.jobs = 1;
        let digest = c1.config_digest();
        c1.journal = Some(Arc::new(Journal::open(&path, false, digest).unwrap()));
        let full = run_experiment("plfp1", &c1).unwrap();
        assert_eq!(full[0].to_csv(), reference[0].to_csv(), "journaling changed the result");

        // Keep the first two journal lines and leave a torn third, as an
        // interrupted write would.
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.len() >= 3, "expected >=3 journaled cells, got {}", lines.len());
        let torn = format!("{}\n{}\n{}", lines[0], lines[1], &lines[2][..lines[2].len() / 2]);
        std::fs::write(&path, torn).unwrap();

        let mut c2 = quick_ctx("res_b");
        c2.jobs = 1;
        let journal = Journal::open(&path, true, digest).unwrap();
        assert_eq!(journal.resumed_cells(), 2, "torn line must not replay");
        c2.journal = Some(Arc::new(journal));
        let resumed = run_experiment("plfp1", &c2).unwrap();
        assert_eq!(resumed[0].to_csv(), reference[0].to_csv(), "resumed CSV diverged");
        assert!(
            resumed[0].notes.iter().any(|n| n.contains("resumed 2 of")),
            "missing resume note: {:?}",
            resumed[0].notes
        );
        let _ = std::fs::remove_file(&path);
    }

    /// PR acceptance: with the injector panicking one cell, the sweep
    /// completes under skip-cell with that cell reported failed and every
    /// other column bit-identical — and under retry it succeeds
    /// bit-identically to the clean run.
    #[test]
    fn injected_fault_is_skipped_or_retried_deterministically() {
        let clean = run_experiment("plfp1", &quick_ctx("inj_ref")).unwrap();
        // Column j of every CSV row, joined; plfp1's columns are
        // [k, pl_exact_bound, pl_sr_bound, Q3.8_RN, Q3.8_SR, signed].
        let cols = |csv: &str, keep: &[usize]| -> Vec<String> {
            csv.lines()
                .map(|l| {
                    let f: Vec<&str> = l.split(',').collect();
                    keep.iter().map(|&j| f[j]).collect::<Vec<_>>().join(",")
                })
                .collect()
        };

        // Cell 1 of plfp1's flat grid is (Q3.8_SR, seed 0): deterministic
        // RN occupies cell 0 alone, so the SR mean loses one seed.
        let mut skip = quick_ctx("inj_skip");
        skip.jobs = 1;
        skip.fault_policy = FaultPolicy::SkipCell;
        skip.injector = Some(Arc::new(FaultInjector::panic_at("plfp1", 1, u32::MAX)));
        let skipped = run_experiment("plfp1", &skip).expect("skip-cell must complete the sweep");
        assert!(
            skipped[0].notes.iter().any(|n| n.contains("failed, skipped")),
            "missing skip note: {:?}",
            skipped[0].notes
        );
        let (csv_clean, csv_skip) = (clean[0].to_csv(), skipped[0].to_csv());
        assert_eq!(
            cols(&csv_clean, &[0, 1, 2, 3, 5]),
            cols(&csv_skip, &[0, 1, 2, 3, 5]),
            "columns untouched by the fault must stay bit-identical"
        );
        assert_ne!(
            cols(&csv_clean, &[4]),
            cols(&csv_skip, &[4]),
            "the SR mean should have lost its seed-0 run"
        );

        // A transient fault (fires once) plus one retry recovers the exact
        // series: the retry re-runs the same pure cell function.
        let mut retry = quick_ctx("inj_retry");
        retry.jobs = 1;
        retry.max_retries = 1;
        retry.injector = Some(Arc::new(FaultInjector::panic_at("plfp1", 1, 1)));
        let retried = run_experiment("plfp1", &retry).expect("retry must recover the sweep");
        assert_eq!(retried[0].to_csv(), clean[0].to_csv(), "retried run must be bit-identical");
        assert!(
            retried[0].notes.iter().any(|n| n.contains("recovered on retry")),
            "missing retry note: {:?}",
            retried[0].notes
        );
    }
}
