//! Process-level coverage of `lpgd serve` (satellite of the experiment
//! service issue): the built binary on an ephemeral port, exercised over
//! real sockets with a hand-rolled HTTP/1.1 client.
//!
//! What only a process test can prove:
//!
//! * the `--addr 127.0.0.1:0` + "listening on http://" startup contract
//!   that scripts and CI parse for the ephemeral port;
//! * bit-identity of served bodies across requests *through the socket
//!   layer* (Content-Length framing and all);
//! * the `/v1/stats` hot-path proof — exactly one miss per unique cell,
//!   every repeat a hit — with the counters observed externally;
//! * a registry warmed by `lpgd reproduce --registry` serving the same
//!   bytes hot, with zero misses.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

/// A running `lpgd serve` child bound to an ephemeral port. Killed on drop
/// so a failing assertion never leaks a daemon.
struct ServeProc {
    child: Child,
    addr: String,
}

impl ServeProc {
    /// Spawn `lpgd serve --registry <dir> --addr 127.0.0.1:0` and parse
    /// the bound address from the startup line.
    fn start(registry: &Path, extra: &[&str]) -> ServeProc {
        let mut child = Command::new(env!("CARGO_BIN_EXE_lpgd"))
            .arg("serve")
            .args(["--registry", &registry.to_string_lossy()])
            .args(["--addr", "127.0.0.1:0", "--threads", "3"])
            .args(extra)
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawn the lpgd binary");
        let mut lines = BufReader::new(child.stdout.take().expect("piped stdout")).lines();
        let addr = loop {
            let line = lines
                .next()
                .expect("server exited before announcing its address")
                .expect("read server stdout");
            // The startup contract scripts rely on: the bound (possibly
            // ephemeral) address on a "listening on http://" line.
            if let Some(rest) = line.strip_prefix("listening on http://") {
                break rest.trim().to_string();
            }
        };
        ServeProc { child, addr }
    }

    /// One HTTP exchange: connect, send, read to EOF (the server always
    /// answers `Connection: close`). Returns `(status, body)`.
    fn request(&self, method: &str, path: &str, body: &str) -> (u16, Vec<u8>) {
        let mut stream = TcpStream::connect(&self.addr).expect("connect to lpgd serve");
        stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        write!(
            stream,
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nContent-Length: {}\r\n\r\n",
            self.addr,
            body.len()
        )
        .unwrap();
        stream.write_all(body.as_bytes()).unwrap();
        let mut raw = Vec::new();
        stream.read_to_end(&mut raw).expect("read the response");
        let head_end = raw
            .windows(4)
            .position(|w| w == b"\r\n\r\n")
            .expect("response has a header/body separator");
        let head = String::from_utf8_lossy(&raw[..head_end]).into_owned();
        let status: u16 = head
            .strip_prefix("HTTP/1.1 ")
            .and_then(|r| r.split_whitespace().next())
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| panic!("malformed status line: {head}"));
        (status, raw[head_end + 4..].to_vec())
    }

    fn get(&self, path: &str) -> (u16, Vec<u8>) {
        self.request("GET", path, "")
    }

    fn post_run(&self, spec: &str) -> (u16, Vec<u8>) {
        self.request("POST", "/v1/run", spec)
    }
}

impl Drop for ServeProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Crude extraction of an integer field from a flat JSON body — enough for
/// `/v1/stats`, and it keeps the test free of a JSON dependency.
fn json_u64(body: &[u8], field: &str) -> u64 {
    let text = std::str::from_utf8(body).expect("JSON body is UTF-8");
    let pat = format!("\"{field}\":");
    let at = text.find(&pat).unwrap_or_else(|| panic!("no '{field}' in {text}"));
    let digits: String =
        text[at + pat.len()..].chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().unwrap_or_else(|_| panic!("no integer after '{field}' in {text}"))
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("lpgd_serve_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// One cell (reps 1) so the miss arithmetic below is exact.
const SPEC_A: &str = r#"{"problem":{"kind":"quadratic1","dim":8},"grid":"bfloat16",
    "stepsize":0.05,"steps":10,"seed":3,"reps":1}"#;
/// Same run, different seed: a second, distinct cell.
const SPEC_B: &str = r#"{"problem":{"kind":"quadratic1","dim":8},"grid":"bfloat16",
    "stepsize":0.05,"steps":10,"seed":4,"reps":1}"#;

/// The tentpole acceptance, observed through the socket: identical specs
/// return byte-identical bodies whether computed or registry-served, a
/// concurrent duplicate coalesces onto one computation, and `/v1/stats`
/// proves the hot path never recomputes — one miss per unique cell, ever.
#[test]
fn served_bodies_are_bit_identical_and_stats_prove_the_hot_path() {
    let dir = temp_dir("identity");
    let server = ServeProc::start(&dir, &["--jobs", "2"]);

    // Cold then warm: the second answer must be the first, byte for byte.
    let (s1, cold) = server.post_run(SPEC_A);
    assert_eq!(s1, 200, "{}", String::from_utf8_lossy(&cold));
    let (s2, warm) = server.post_run(SPEC_A);
    assert_eq!(s2, 200);
    assert_eq!(cold, warm, "registry-served body differs from the computed one");

    // A concurrent identical pair on a fresh cell: both answers 200 and
    // byte-identical, but only one computation behind them.
    let (ra, rb) = std::thread::scope(|scope| {
        let a = scope.spawn(|| server.post_run(SPEC_B));
        let b = scope.spawn(|| server.post_run(SPEC_B));
        (a.join().unwrap(), b.join().unwrap())
    });
    assert_eq!(ra.0, 200, "{}", String::from_utf8_lossy(&ra.1));
    assert_eq!(rb.0, 200);
    assert_eq!(ra.1, rb.1, "concurrent duplicates must serve the same bytes");

    // The counters tell the whole story: two unique cells → exactly two
    // misses; the sequential repeat and the coalesced duplicate → hits.
    let (ss, stats) = server.get("/v1/stats");
    assert_eq!(ss, 200);
    assert_eq!(json_u64(&stats, "misses"), 2, "{}", String::from_utf8_lossy(&stats));
    assert_eq!(json_u64(&stats, "hits"), 2, "{}", String::from_utf8_lossy(&stats));
    assert_eq!(json_u64(&stats, "in_flight"), 0);
    assert_eq!(json_u64(&stats, "cached_cells"), 2);

    // The response embeds each cell's registry key; the key dereferences
    // through GET /v1/result to the same record.
    let body = String::from_utf8_lossy(&cold).into_owned();
    let at = body.find("\"key\":\"").expect("response carries the registry key") + 7;
    let key = &body[at..at + 16];
    let (sr, rec) = server.get(&format!("/v1/result/{key}"));
    assert_eq!(sr, 200);
    let rec = String::from_utf8_lossy(&rec);
    assert!(rec.contains(&format!("\"key\":\"{key}\"")), "{rec}");
    assert!(rec.contains("\"series\""), "{rec}");

    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Error paths through the socket: malformed specs get descriptive `400`s
/// (the parse error verbatim), unknown routes `404`, wrong methods `405`.
#[test]
fn malformed_requests_get_descriptive_errors() {
    let dir = temp_dir("errors");
    let server = ServeProc::start(&dir, &[]);

    let (s, b) = server.post_run("this is not json");
    assert_eq!(s, 400);
    assert!(
        String::from_utf8_lossy(&b).contains("not valid JSON"),
        "{}",
        String::from_utf8_lossy(&b)
    );

    let (s, b) = server.post_run(
        r#"{"problem":{"kind":"quadratic1","dim":8},"grid":"binary7",
            "stepsize":0.05,"steps":10}"#,
    );
    assert_eq!(s, 400);
    let b = String::from_utf8_lossy(&b);
    assert!(b.contains("binary7") && b.contains("bfloat16"), "names the fix: {b}");

    let (s, b) = server.post_run(r#"{"problem":{"kind":"quadratic1","dim":8},
        "grid":"binary8","stepsize":0.05,"steps":10,"step_size":1}"#);
    assert_eq!(s, 400);
    assert!(String::from_utf8_lossy(&b).contains("unknown spec field 'step_size'"));

    let (s, _) = server.get("/v1/nope");
    assert_eq!(s, 404);
    let (s, _) = server.request("DELETE", "/v1/run", "");
    assert_eq!(s, 405);
    let (s, b) = server.get("/v1/result/xyz");
    assert_eq!(s, 400);
    assert!(String::from_utf8_lossy(&b).contains("16-hex-digit"));

    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The CLI/service round trip: a registry warmed offline by
/// `lpgd reproduce --registry` serves the experiment hot — the `text/csv`
/// body is byte-identical to the CSV the CLI wrote, and `/v1/stats`
/// records zero misses (nothing recomputed).
#[test]
fn registry_warmed_by_cli_serves_hot_and_bit_identical() {
    let base = temp_dir("warm");
    let registry = base.join("registry");
    let out = base.join("results");
    std::fs::create_dir_all(&base).unwrap();

    let cli = Command::new(env!("CARGO_BIN_EXE_lpgd"))
        .args(["reproduce", "fig3a", "--quick", "--seeds", "2"])
        .args(["--quad-n", "16", "--quad-steps", "30", "--jobs", "1"])
        .args(["--registry", &registry.to_string_lossy()])
        .args(["--out-dir", &out.to_string_lossy()])
        .output()
        .expect("spawn the lpgd binary");
    assert!(
        cli.status.success(),
        "warm-up reproduce failed:\n{}",
        String::from_utf8_lossy(&cli.stderr)
    );
    let offline = std::fs::read(out.join("fig3a.csv")).expect("reproduce wrote fig3a.csv");

    let server = ServeProc::start(&registry, &["--jobs", "1"]);
    let spec = r#"{"experiment":"fig3a","seeds":2,"quad_n":16,"quad_steps":30,
        "format":"csv"}"#;
    let (s, served) = server.post_run(spec);
    assert_eq!(s, 200, "{}", String::from_utf8_lossy(&served));
    assert_eq!(
        served, offline,
        "served CSV differs from the offline `reproduce` output"
    );

    let (ss, stats) = server.get("/v1/stats");
    assert_eq!(ss, 200);
    assert_eq!(
        json_u64(&stats, "misses"),
        0,
        "a warmed registry must serve without recomputation: {}",
        String::from_utf8_lossy(&stats)
    );
    assert!(json_u64(&stats, "hits") > 0, "{}", String::from_utf8_lossy(&stats));

    drop(server);
    let _ = std::fs::remove_dir_all(&base);
}
