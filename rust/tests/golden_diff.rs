//! Golden-figure replication suite (the "golden" test tier, see
//! `docs/testing.md` and ROADMAP item 4).
//!
//! The first test diffs fresh scheduler output for **every** registered
//! experiment against the checked-in artifacts under `goldens/` — one
//! looping test rather than one `#[test]` per figure so libtest's
//! parallelism never races two checks over the shared goldens directory.
//! Missing goldens bootstrap via a double-run determinism proof (and the
//! test prints a commit reminder); from a clean checkout the suite
//! therefore passes twice in a row — run one bootstraps, run two diffs.
//!
//! Environment knobs (both read by this suite only):
//!
//! * `LPGD_GOLDEN_REQUIRE=1` — fail on missing goldens instead of
//!   bootstrapping (the `scripts/verify.sh` golden stage and CI mode).
//! * `LPGD_GOLDEN_STREAM_CHANGE=1` — compare SEM-banded stochastic
//!   columns under CLT tolerance bands instead of byte-exactly, for
//!   validating an intentional RNG stream change. Per-point false-failure
//!   probability 1e-9; union-bounded over a full suite run the spurious
//!   failure probability stays below ~5e-6 (see `coordinator::goldens`).
//!
//! The default tier is byte-exact for every column — stochastic curves
//! included, because fixed seeds make them bit-reproducible — so the
//! default false-failure probability is 0.

use std::fs;
use std::path::{Path, PathBuf};

use lpgd::coordinator::goldens::{self, CheckOpts, CheckStatus};
use lpgd::coordinator::registry::REGISTRY;

fn repo_goldens() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("goldens")
}

fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| v == "1").unwrap_or(false)
}

/// The headline check: every figure experiment + the expected-round bias
/// table vs `goldens/`.
#[test]
fn golden_figures_match_or_bootstrap() {
    let opts = CheckOpts {
        require: env_flag("LPGD_GOLDEN_REQUIRE"),
        stream_change: env_flag("LPGD_GOLDEN_STREAM_CHANGE"),
    };
    let dir = repo_goldens();
    let ctx = goldens::golden_ctx();
    let report = goldens::check(&dir, &ctx, &opts).expect("golden check must run");
    print!("{}", report.to_text());
    let boots = report.bootstrapped();
    if !boots.is_empty() {
        println!(
            "bootstrapped golden(s) under {} — commit them: {}",
            dir.display(),
            boots.join(", ")
        );
    }
    // One entry per registered experiment plus the expected-round table.
    assert!(
        report.entries.len() >= REGISTRY.len() + 1,
        "expected >= {} entries, got {}",
        REGISTRY.len() + 1,
        report.entries.len()
    );
    assert!(
        report.passed(),
        "golden check failed — see the entries above; docs/testing.md explains \
         how to read a byte-exact or tolerance-band failure and when to rerun \
         `lpgd goldens extract`"
    );
}

/// Sensitivity: a minimally perturbed golden (1 ulp in the bit-pattern
/// table, one trailing rendered digit in a figure CSV) must fail the
/// check, and a missing golden must fail under `require` with remediation
/// text — exercised in a throwaway directory so the checked-in goldens
/// stay untouched.
#[test]
fn golden_check_rejects_perturbations_and_missing_goldens() {
    let dir = std::env::temp_dir().join(format!("lpgd_golden_it_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    let ctx = goldens::golden_ctx();
    let open = CheckOpts::default();

    // Bootstrap everything via the double-run determinism proof.
    let r = goldens::check(&dir, &ctx, &open).expect("bootstrap check");
    assert!(r.passed(), "{}", r.to_text());
    assert!(
        r.entries.iter().all(|e| e.status == CheckStatus::Bootstrapped),
        "{}",
        r.to_text()
    );

    // Perturb the expected-round table by exactly 1 ulp (hex bit edit) and
    // one figure CSV by its smallest rendered increment (last digit).
    let er = dir.join("expected_round_binary8.csv");
    let text = fs::read_to_string(&er).unwrap();
    let mut lines: Vec<String> = text.lines().map(String::from).collect();
    let mut cells: Vec<String> = lines[5].split(',').map(String::from).collect();
    let bits = u64::from_str_radix(&cells[1], 16).unwrap();
    cells[1] = format!("{:016x}", bits + 1);
    lines[5] = cells.join(",");
    fs::write(&er, format!("{}\n", lines.join("\n"))).unwrap();

    let fig = dir.join("table2.csv");
    let text = fs::read_to_string(&fig).unwrap();
    let mut lines: Vec<String> = text.lines().map(String::from).collect();
    let bumped = lines[1]
        .chars()
        .rev()
        .find(|c| c.is_ascii_digit())
        .expect("a numeric cell to perturb");
    let replacement = if bumped == '1' { '2' } else { '1' };
    let pos = lines[1].rfind(bumped).unwrap();
    lines[1].replace_range(pos..pos + 1, &replacement.to_string());
    fs::write(&fig, format!("{}\n", lines.join("\n"))).unwrap();

    let r = goldens::check(&dir, &ctx, &open).expect("perturbed check");
    assert!(!r.passed(), "perturbations must be caught:\n{}", r.to_text());
    let fails: Vec<&str> = r
        .entries
        .iter()
        .filter(|e| e.status == CheckStatus::Fail)
        .map(|e| e.id.as_str())
        .collect();
    assert_eq!(fails, vec!["table2", "expected_round_binary8"], "{}", r.to_text());
    let er_fail = r.entries.iter().find(|e| e.id == "expected_round_binary8").unwrap();
    assert!(er_fail.detail.contains("1 ulp"), "{}", er_fail.detail);
    let fig_fail = r.entries.iter().find(|e| e.id == "table2").unwrap();
    assert!(fig_fail.detail.contains("golden"), "{}", fig_fail.detail);

    // A deleted golden under `require` fails with remediation instead of
    // silently bootstrapping.
    fs::remove_file(&fig).unwrap();
    let strict = CheckOpts { require: true, stream_change: false };
    let r = goldens::check(&dir, &ctx, &strict).expect("require check");
    assert!(!r.passed());
    let missing = r.entries.iter().find(|e| e.id == "table2").unwrap();
    assert_eq!(missing.status, CheckStatus::Fail);
    assert!(missing.detail.contains("extract"), "{}", missing.detail);
    assert!(!dir.join("table2.csv").exists(), "require mode must not bootstrap");

    let _ = fs::remove_dir_all(&dir);
}
