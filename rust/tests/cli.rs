//! CLI error-path coverage, driven through the built binary with
//! `std::process::Command` (satellite of the golden-harness issue): every
//! malformed invocation must exit non-zero with a descriptive message —
//! never run with silently-defaulted options. Each case below exercises a
//! path that fails *before* any experiment work starts, so the whole
//! suite is cheap.

use std::process::{Command, Output};

fn lpgd(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_lpgd"))
        .args(args)
        .output()
        .expect("spawn the lpgd binary")
}

fn run_err(args: &[&str]) -> String {
    let out = lpgd(args);
    assert!(
        !out.status.success(),
        "`lpgd {}` unexpectedly succeeded:\n{}",
        args.join(" "),
        String::from_utf8_lossy(&out.stdout)
    );
    String::from_utf8_lossy(&out.stderr).into_owned()
}

#[test]
fn unknown_command_is_rejected() {
    let err = run_err(&["frobnicate"]);
    assert!(err.contains("unknown command 'frobnicate'"), "{err}");
    assert!(err.contains("--help"), "{err}");
}

#[test]
fn unknown_options_are_rejected_per_subcommand() {
    // The historic failure mode was a silent ignore: `--sceme` trained
    // with the default scheme. Every subcommand must reject typos.
    let err = run_err(&["list", "--bogus", "1"]);
    assert!(err.contains("unknown option(s): --bogus"), "{err}");
    let err = run_err(&["reproduce", "table2", "--sceme", "sr"]);
    assert!(err.contains("unknown option(s): --sceme"), "{err}");
    let err = run_err(&["train", "mlr", "--epocs", "3"]);
    assert!(err.contains("unknown option(s): --epocs"), "{err}");
    let err = run_err(&["round", "1.1", "--frmt", "binary8"]);
    assert!(err.contains("unknown option(s): --frmt"), "{err}");
    let err = run_err(&["goldens", "check", "--bogus", "1"]);
    assert!(err.contains("unknown option(s): --bogus"), "{err}");
}

#[test]
fn value_options_missing_their_value_are_rejected() {
    // `--scheme` as the last token parses as a flag; it must be reported
    // instead of silently training with the default scheme.
    let err = run_err(&["train", "mlr", "--scheme"]);
    assert!(err.contains("missing a value: --scheme"), "{err}");
}

#[test]
fn malformed_scheme_specs_are_rejected() {
    let err = run_err(&["train", "mlr", "--scheme", "nope"]);
    assert!(err.contains("unknown rounding scheme 'nope'"), "{err}");
    // The error lists the registered schemes so the fix is one read away.
    assert!(err.contains("sr_eps"), "{err}");
    let err = run_err(&["train", "mlr", "--scheme", "sr_eps:abc"]);
    assert!(err.contains("bad parameter 'abc'"), "{err}");
    let err = run_err(&["round", "1.1", "--mode", "sr_eps:abc"]);
    assert!(err.contains("bad parameter 'abc'"), "{err}");
}

#[test]
fn malformed_grid_and_backend_specs_are_rejected() {
    let err = run_err(&["round", "1.1", "--backend", "q99.99"]);
    assert!(err.contains("unknown --backend/--fmt 'q99.99'"), "{err}");
    let err = run_err(&["round", "1.1", "--fmt", "binary7"]);
    assert!(err.contains("binary7"), "{err}");
    // A non-numeric positional for `round` fails the f64 parse.
    let err = run_err(&["round", "abc"]);
    assert!(err.contains("error"), "{err}");
}

#[test]
fn resume_without_journal_is_rejected() {
    let err = run_err(&["reproduce", "table2", "--resume"]);
    assert!(err.contains("--resume requires --journal"), "{err}");
}

#[test]
fn unknown_experiment_and_goldens_action_are_rejected() {
    let err = run_err(&["reproduce", "nosuchfig"]);
    assert!(err.contains("unknown experiment 'nosuchfig'"), "{err}");
    let err = run_err(&["goldens", "frobnicate"]);
    assert!(err.contains("unknown goldens action 'frobnicate'"), "{err}");
}

#[test]
fn lanes_zero_and_malformed_lanes_are_rejected() {
    let err = run_err(&["reproduce", "table2", "--lanes", "0"]);
    assert!(err.contains("--lanes must be at least 1"), "{err}");
    let err = run_err(&["reproduce", "table2", "--lanes", "four"]);
    assert!(err.contains("--lanes takes a positive integer"), "{err}");
}

#[test]
fn malformed_simd_backend_is_rejected() {
    let err = run_err(&["reproduce", "table2", "--simd", "avx512"]);
    assert!(err.contains("unknown SIMD backend 'avx512'"), "{err}");
    assert!(err.contains("auto, avx2 or scalar"), "{err}");
}

/// `--lanes` composes with `--journal`/`--resume`: cells journaled by a
/// lane-batched sweep replay bit-identically into a resume at a different
/// lane width, and the CSVs match a fresh run at width 1 byte for byte.
#[test]
fn journaled_cells_replay_bit_identically_across_lane_widths() {
    let base = std::env::temp_dir().join(format!("lpgd_cli_lanes_{}", std::process::id()));
    let journal = base.join("sweep.jsonl");
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).unwrap();
    let jpath = journal.to_string_lossy().into_owned();
    let run_ok = |out_dir: &str, extra: &[&str]| {
        let dir = base.join(out_dir);
        let mut args = vec![
            "reproduce",
            "fig3a",
            "--quick",
            "--quad-n",
            "10",
            "--quad-steps",
            "40",
            "--seeds",
            "3",
            "--jobs",
            "1",
            "--out-dir",
        ];
        let dir_s = dir.to_string_lossy().into_owned();
        args.push(&dir_s);
        args.extend_from_slice(extra);
        let out = lpgd(&args);
        assert!(
            out.status.success(),
            "`lpgd {}` failed:\n{}",
            args.join(" "),
            String::from_utf8_lossy(&out.stderr)
        );
        (dir, String::from_utf8_lossy(&out.stderr).into_owned())
    };
    // Fresh lane-batched run writes the journal.
    let (dir_wide, _) = run_ok("wide", &["--lanes", "4", "--journal", &jpath]);
    // Resume at a different width: every cell replays from the journal.
    let (dir_resumed, stderr) =
        run_ok("resumed", &["--lanes", "1", "--journal", &jpath, "--resume"]);
    assert!(stderr.contains("completed cell(s) loaded"), "{stderr}");
    // Fresh scalar-width run, no journal at all.
    let (dir_scalar, _) = run_ok("scalar", &["--lanes", "1"]);
    let csv = |dir: &std::path::Path| {
        std::fs::read_to_string(dir.join("fig3a.csv")).expect("fig3a.csv written")
    };
    let wide = csv(&dir_wide);
    assert!(!wide.is_empty());
    assert_eq!(wide, csv(&dir_resumed), "journal replay changed the CSV");
    assert_eq!(wide, csv(&dir_scalar), "lane width changed the CSV");
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn help_lists_the_new_subcommand_and_exits_zero() {
    let out = lpgd(&["--help"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("goldens <extract|check>"), "{text}");
    assert!(text.contains("registered rounding schemes"), "{text}");
}
