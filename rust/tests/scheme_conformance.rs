//! Scheme-conformance suite: every scheme reachable through the
//! [`SchemeRegistry`] — built-in families at several parameterizations plus
//! a custom scheme registered in-test — must
//!
//! * round to representable values ((saturated) neighbors of the input,
//!   fixed points on representable inputs),
//! * match its closed-form [`Scheme::expected_round`] within Monte-Carlo
//!   tolerance,
//! * consume zero random bits when deterministic,
//!
//! **on both backends** — the float formats and a fixed-point Qm.n grid
//! (the PR-4 acceptance constraint: the trait surface is format-generic) —
//! and the registry/builder path must produce **bit-identical** GD
//! trajectories to the pre-redesign enum path for every built-in scheme
//! (the redesign's hard acceptance constraint).

use lpgd::fp::{
    FixedPoint, FpFormat, Grid, NumberGrid, Rng, RoundPlan, Rounding, RoundingScheme, Scheme,
    SchemeRegistry,
};
use lpgd::gd::engine::{GdConfig, GdEngine, PolicyMap, TensorPolicy};
use lpgd::gd::optimizer::OptimizerSpec;
use lpgd::gd::RunBuilder;
use lpgd::problems::Quadratic;

const B8: FpFormat = FpFormat::BINARY8;
const Q3_8: FixedPoint = FixedPoint::q(3, 8);

/// The grids every conformance property runs over: two float formats and
/// one fixed-point grid.
fn conformance_grids() -> Vec<Grid> {
    vec![Grid::Float(B8), Grid::Float(FpFormat::BFLOAT16), Grid::Fixed(Q3_8)]
}

/// Spec strings covering every built-in family, parameterized variants
/// included.
fn builtin_specs() -> Vec<&'static str> {
    vec!["rn", "rd", "ru", "rz", "sr", "sr_eps:0.1", "sr_eps:0.4", "signed_sr_eps:0.25"]
}

/// Every scheme the conformance properties run against: the built-ins plus
/// the in-test custom scheme.
fn all_schemes() -> Vec<Scheme> {
    let mut out: Vec<Scheme> =
        builtin_specs().into_iter().map(|s| SchemeRegistry::lookup(s).unwrap()).collect();
    out.push(coin_flip());
    out
}

// ------------------------------------------------- the custom toy scheme --

/// "Coin flip" rounding: an inexact value goes to its (saturated) floor or
/// ceiling with probability ½ each, regardless of position in the gap —
/// a deliberately non-paper law proving the API is open. Written against
/// the grid-generic `NumberGrid` surface, so it runs on both backends
/// unchanged. Expected value: the gap midpoint.
struct CoinFlip;

fn sat(grid: &Grid, y: f64) -> f64 {
    grid.saturate(y)
}

impl RoundingScheme for CoinFlip {
    fn name(&self) -> String {
        "coin_flip".into()
    }
    fn label(&self) -> String {
        "CoinFlip".into()
    }
    fn is_stochastic(&self) -> bool {
        true
    }
    fn round(&self, plan: &RoundPlan, x: f64, _v: f64, rng: &mut Rng) -> f64 {
        if x == 0.0 || x.is_nan() {
            return x;
        }
        let (lo, hi) = plan.grid.floor_ceil(x);
        if lo == hi {
            return lo;
        }
        let (lo, hi) = (sat(&plan.grid, lo), sat(&plan.grid, hi));
        if lo == hi {
            return lo;
        }
        if rng.uniform() < 0.5 {
            lo
        } else {
            hi
        }
    }
    fn expected_round(&self, grid: &Grid, x: f64, _v: f64) -> f64 {
        if x == 0.0 || x.is_nan() {
            return x;
        }
        let (lo, hi) = grid.floor_ceil(x);
        if lo == hi {
            return lo;
        }
        let (lo, hi) = (sat(grid, lo), sat(grid, hi));
        0.5 * (lo + hi)
    }
}

static COIN_FLIP: CoinFlip = CoinFlip;

/// Register (idempotently — tests share the process) and return the custom
/// scheme through a registry lookup, proving the full name→scheme path.
fn coin_flip() -> Scheme {
    let _ = SchemeRegistry::register(&COIN_FLIP);
    SchemeRegistry::lookup("coin_flip").expect("custom scheme must resolve")
}

// ------------------------------------------------ conformance properties --

fn test_inputs(grid: &Grid) -> Vec<f64> {
    let mut rng = Rng::new(1234);
    // Bulk samples scaled inside the grid's dynamic range (1e3 keeps the
    // float cases identical to the historic suite; the fixed grid's whole
    // range is exercised).
    let span = grid.max_value().min(1e3);
    let mut xs: Vec<f64> = (0..300).map(|_| rng.normal() * span).collect();
    let tiny = grid.successor(0.0); // smallest positive grid point
    xs.extend([
        0.0,
        1.0,
        -1.25,
        tiny * 0.3,
        -tiny * 0.5,
        grid.max_value() * 1.5,
        -grid.max_value() * 2.0,
        f64::INFINITY,
        f64::NAN,
    ]);
    // Float grids: also hit the subnormal *interior* (between the smallest
    // subnormal and the smallest normal), where both neighbors are
    // subnormal — `tiny` only probes below the subnormal range.
    if let Some(f) = grid.as_float() {
        xs.extend([f.x_min() * 0.3, -f.x_min() * 0.3, f.x_min() * 0.97, -f.x_min() * 0.97]);
    }
    xs
}

/// Property 1: outputs are fixed points on representable inputs and
/// (saturated) neighbors otherwise, for scalar and slice entry points —
/// on float and fixed-point grids alike.
#[test]
fn rounds_to_representable_neighbors() {
    for grid in conformance_grids() {
        let plan = RoundPlan::new(grid);
        let xs = test_inputs(&grid);
        for scheme in all_schemes() {
            let mut rng = Rng::new(5);
            let mut slice = xs.clone();
            plan.round_slice_scheme(scheme, &mut slice, &mut Rng::new(6));
            for (i, &x) in xs.iter().enumerate() {
                let y = plan.round_scheme(scheme, x, &mut rng);
                for (entry, got) in [("scalar", y), ("slice", slice[i])] {
                    if x.is_nan() {
                        assert!(got.is_nan(), "{} {entry}: NaN in, {got} out", scheme.name());
                        continue;
                    }
                    let (lo, hi) = grid.floor_ceil(x);
                    let (slo, shi) = (sat(&grid, lo), sat(&grid, hi));
                    assert!(
                        got == lo || got == hi || got == slo || got == shi,
                        "{} {entry} {}: {got} is not a (saturated) neighbor of {x}",
                        scheme.name(),
                        grid.label()
                    );
                    if grid.contains(x) {
                        assert_eq!(got, x, "{} {entry}: representable {x} moved", scheme.name());
                    }
                }
            }
        }
    }
}

/// Property 2: the closed-form `expected_round` matches the empirical mean
/// of the scalar law within Monte-Carlo tolerance (exactly, for
/// deterministic schemes and for saturated out-of-range inputs) — on both
/// backends. Fixed seed; every draw lies in one gap, so by Hoeffding each
/// stochastic assertion fails spuriously with probability ≤ 2.5e-14 (the
/// p for which the half-width equals the historic `4·gap/√n` tolerance —
/// see `util::stats::hoeffding_halfwidth` and docs/testing.md).
#[test]
fn expected_round_matches_empirical_mean() {
    for grid in conformance_grids() {
        let plan = RoundPlan::new(grid);
        for scheme in all_schemes() {
            let mut rng = Rng::new(77);
            for &(x, v) in &[(1.1, -1.0), (-2.6, 2.0), (0.013, 1.0), (900.0, -3.0)] {
                let want = scheme.expected_round(grid, x, v);
                let (lo, hi) = grid.floor_ceil(x);
                let gap = sat(&grid, hi) - sat(&grid, lo);
                if !scheme.is_stochastic() || gap == 0.0 {
                    let got = plan.round_scheme_with(scheme, x, v, &mut rng);
                    // Deterministic RN may legitimately overflow to ±∞ on a
                    // float grid while the saturating expectation clamps;
                    // skip the one overflow × deterministic combination.
                    if got.is_finite() {
                        assert_eq!(
                            got,
                            want,
                            "{} {} exact expectation x={x}",
                            scheme.name(),
                            grid.label()
                        );
                    }
                    continue;
                }
                let n = 40_000;
                let mean: f64 = (0..n)
                    .map(|_| plan.round_scheme_with(scheme, x, v, &mut rng))
                    .sum::<f64>()
                    / n as f64;
                let tol = lpgd::util::stats::hoeffding_halfwidth(gap, n, 2.5e-14);
                assert!(
                    (mean - want).abs() < tol,
                    "{} {} x={x} v={v}: mean {mean} vs closed form {want} (tol {tol})",
                    scheme.name(),
                    grid.label()
                );
            }
        }
    }
}

/// Property 3: deterministic schemes consume zero random bits through both
/// the scalar and the slice entry points — on both backends.
#[test]
fn deterministic_schemes_consume_no_randomness() {
    for grid in conformance_grids() {
        let plan = RoundPlan::new(grid);
        let xs = test_inputs(&grid);
        for scheme in all_schemes().into_iter().filter(|s| !s.is_stochastic()) {
            let mut rng = Rng::new(21);
            for &x in &xs {
                let _ = plan.round_scheme(scheme, x, &mut rng);
            }
            let mut buf = xs.clone();
            plan.round_slice_scheme(scheme, &mut buf, &mut rng);
            let mut fresh = Rng::new(21);
            assert_eq!(
                rng.next_u64(),
                fresh.next_u64(),
                "{} on {}: deterministic scheme consumed randomness",
                scheme.name(),
                grid.label()
            );
            assert_eq!(scheme.bits_per_element(&plan), 0, "{}", scheme.name());
        }
        // And the stochastic ones advertise their slice bit budget: the
        // fused few-random-bits path for built-ins, the full-word scalar
        // fallback for custom schemes (CoinFlip draws one `Rng::uniform`
        // per element).
        assert_eq!(Scheme::sr().bits_per_element(&plan), plan.sr_bits());
        assert_eq!(coin_flip().bits_per_element(&plan), 64);
    }
}

/// Robustness satellite: [`RunHealth`] saturation counts agree with an
/// exhaustive oracle on the tiny Q2.3 grid, for every scheme in the
/// registry. Saturation is classified on the *pre-image* (a finite input
/// strictly outside the representable range), so the expected count is the
/// same for every scheme — deterministic or stochastic — and can be
/// computed independently by materializing the whole grid. Underflow and
/// nan_inf counts are cross-checked against the realized outputs.
#[test]
fn run_health_saturations_match_the_exhaustive_q23_oracle() {
    use lpgd::fp::RunHealth;

    let fx = FixedPoint::q(2, 3);
    let grid: Grid = fx.into();
    let d = fx.delta();
    let (k_min, k_max) = (-(1i64 << (fx.word_bits - 1)), (1i64 << (fx.word_bits - 1)) - 1);
    let pts: Vec<f64> = (k_min..=k_max).map(|k| k as f64 * d).collect();
    let (min, max) = (NumberGrid::min_value(&fx), NumberGrid::max_value(&fx));
    assert_eq!((pts[0], *pts.last().unwrap()), (min, max));

    // Exhaustive inputs: every grid point, every midpoint and quarter
    // point, out-of-range magnitudes on both sides, and the specials.
    let mut inputs: Vec<f64> = pts.clone();
    for w in pts.windows(2) {
        inputs.push((w[0] + w[1]) / 2.0);
        inputs.push(w[0] + 0.25 * d);
    }
    inputs.extend([
        max + 0.4 * d,
        max + 10.0,
        min - 0.4 * d,
        min - 10.0,
        f64::INFINITY,
        f64::NEG_INFINITY,
        f64::NAN,
    ]);

    // Independent oracle: finite and strictly outside [min, max] — the
    // grid itself plays no part in the count.
    let want_sat =
        inputs.iter().filter(|x| x.is_finite() && (**x < min || **x > max)).count() as u64;
    assert!(want_sat >= 4, "the input set must exercise both saturation sides");

    for scheme in all_schemes() {
        let plan = RoundPlan::new(grid);
        let mut health = RunHealth::default();
        let mut xs = inputs.clone();
        let vs = inputs.clone();
        let mut rng = Rng::new(3);
        plan.round_slice_scheme_health(scheme, &mut xs, &vs, &mut rng, &mut health);
        assert_eq!(health.saturations, want_sat, "{} saturation count", scheme.name());
        assert_eq!(
            health.nan_inf,
            0,
            "{}: a saturating fixed grid never fabricates non-finites",
            scheme.name()
        );
        // Underflow oracle from the realized outputs: nonzero in-range
        // pre-image, exactly-zero image.
        let want_under = inputs
            .iter()
            .zip(&xs)
            .filter(|&(&b, &a)| b.is_finite() && min <= b && b <= max && b != 0.0 && a == 0.0)
            .count() as u64;
        assert_eq!(health.underflows, want_under, "{} underflow count", scheme.name());
        assert_eq!(health.stalled_steps, 0);
        assert_eq!(health.steps, 0);
    }
}

// ------------------------------------- bit-equality vs the pre-redesign --

/// The registry + `RunBuilder` path produces bit-identical GD trajectories
/// to the legacy enum path (`Rounding::parse` + `From<Rounding> for
/// PolicyMap` + `GdConfig::new`) for every built-in scheme.
#[test]
fn builder_trajectories_bit_identical_to_enum_path() {
    let p = Quadratic::diagonal(vec![1.0], vec![100.0]);
    for spec in builtin_specs() {
        let mode = Rounding::parse(spec).unwrap();
        let mut cfg = GdConfig::new(B8, mode, 0.1, 60);
        cfg.seed = 3;
        let mut legacy = GdEngine::new(cfg, &p, &[1.0]);
        let legacy_series = legacy.run(None).objective_series();

        let mut session = RunBuilder::new(&p)
            .format(B8)
            .scheme(spec)
            .stepsize(0.1)
            .steps(60)
            .seed(3)
            .start(&[1.0])
            .build()
            .unwrap();
        let built_series = session.run(None).objective_series();

        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&legacy_series), bits(&built_series), "{spec} trajectory");
        assert_eq!(bits(&legacy.x), bits(session.x()), "{spec} final iterate");
    }
}

/// A custom registered scheme drives a full GD run through the builder:
/// the API is open end-to-end, and the iterate stays format-resident.
#[test]
fn custom_scheme_runs_gd_end_to_end() {
    let scheme = coin_flip();
    let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
    // Mixed per-tensor policy: custom law on (8c), built-ins elsewhere.
    let mut session = RunBuilder::new(&p)
        .format(B8)
        .scheme("sr")
        .sub_scheme("coin_flip")
        .stepsize(0.05)
        .steps(40)
        .seed(9)
        .start(&[1.0])
        .build()
        .unwrap();
    let tr = session.run(None);
    assert_eq!(tr.records.len(), 40);
    assert!(session.x().iter().all(|&v| B8.contains(v)), "iterate left the format");
    assert!(tr.final_f().is_finite());
    // Uniform custom policy works too, and is reproducible per seed.
    let run = |seed: u64| {
        let mut s = RunBuilder::new(&p)
            .format(B8)
            .policy(scheme)
            .stepsize(0.05)
            .steps(30)
            .seed(seed)
            .start(&[1.0])
            .build()
            .unwrap();
        s.run(None).objective_series()
    };
    assert_eq!(run(4), run(4), "custom scheme must be a pure function of the stream");
    assert_ne!(run(4), run(5), "distinct seeds must decorrelate the custom law");
}

// --------------------------------------------- optimizer-state tensors --

/// Optimizer-state conformance: every registered scheme — the built-ins at
/// several parameterizations plus the in-test custom CoinFlip — drives the
/// momentum and Adam state tensors on the bfloat16 and binary16 grids.
/// The state must stay resident on its grid, be enumerable by stable name
/// through [`GdEngine::state_names`] / [`GdEngine::state_tensor`], and a
/// [`TensorPolicy`] binding must move it to the bound grid.
#[test]
fn every_scheme_rounds_optimizer_state_on_half_precision_grids() {
    let p = Quadratic::diagonal(vec![1.0, 0.25], vec![6.0, -3.0]);
    let opts =
        [OptimizerSpec::Momentum { beta: 0.9 }, OptimizerSpec::Adam {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }];
    for fmt in [FpFormat::BFLOAT16, FpFormat::BINARY16] {
        for scheme in all_schemes() {
            for opt in opts {
                let mut cfg = GdConfig::new(fmt, PolicyMap::uniform(scheme), 0.1, 30);
                cfg.seed = 11;
                cfg.optimizer = opt;
                let mut e = GdEngine::new(cfg, &p, &[0.5, 0.5]);
                let tr = e.run(None);
                assert!(tr.final_f().is_finite(), "{} {opt:?} on {fmt:?}", scheme.name());
                assert_eq!(e.state_names(), opt.state_names(), "{}", scheme.name());
                for name in opt.state_names() {
                    let s = e.state_tensor(name).expect("named state tensor must resolve");
                    assert!(
                        s.iter().all(|&v| fmt.contains(v)),
                        "{}: state '{name}' left {fmt:?} under {opt:?}",
                        scheme.name()
                    );
                }
                assert!(e.state_tensor("nope").is_none());
                assert!(e.health.nan_inf == 0, "{}: state produced non-finites", scheme.name());
            }
        }
    }
    // A state binding moves the tensor to the bound grid: `m` accumulates
    // on binary32 while the iterate stays bfloat16-resident.
    let pol = PolicyMap::uniform(Scheme::sr())
        .with_m(TensorPolicy::new(Scheme::rn()).on(FpFormat::BINARY32));
    let mut cfg = GdConfig::new(FpFormat::BFLOAT16, pol, 0.1, 30);
    cfg.seed = 4;
    cfg.optimizer = OptimizerSpec::Momentum { beta: 0.9 };
    let mut e = GdEngine::new(cfg, &p, &[0.5, 0.5]);
    e.run(None);
    assert!(e.x.iter().all(|&v| FpFormat::BFLOAT16.contains(v)), "iterate left bfloat16");
    let m = e.state_tensor("m").expect("momentum buffer");
    assert!(m.iter().all(|&v| FpFormat::BINARY32.contains(v)), "bound m left binary32");
}

/// `Rounding::parse` (the deprecated shim) reports registered customs with
/// a targeted error instead of a silent `None`.
#[test]
fn rounding_parse_rejects_custom_schemes_descriptively() {
    let _ = coin_flip();
    let err = Rounding::parse("coin_flip").unwrap_err().to_string();
    assert!(err.contains("coin_flip") && err.contains("not a built-in"), "{err}");
}
