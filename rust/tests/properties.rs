//! Property-based tests over the fp substrate (proptest is not vendored in
//! this offline image, so this is a seeded-sweep driver with the same
//! spirit: thousands of random inputs per invariant, failures print the
//! offending input).

use lpgd::fp::{expected_round, round, round_with, FpFormat, Rng, Rounding};

const FORMATS: [FpFormat; 4] =
    [FpFormat::BINARY8, FpFormat::BFLOAT16, FpFormat::BINARY16, FpFormat::BINARY32];

const MODES: [Rounding; 7] = [
    Rounding::RoundNearestEven,
    Rounding::RoundDown,
    Rounding::RoundUp,
    Rounding::RoundTowardZero,
    Rounding::Sr,
    Rounding::SrEps(0.3),
    Rounding::SignedSrEps(0.3),
];

/// Random values spanning many binades, both signs, including format
/// boundary magnitudes and subnormal ranges.
fn gen_values(fmt: &FpFormat, n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut vals = Vec::with_capacity(n + 16);
    for _ in 0..n {
        let e = rng.uniform_in(fmt.e_min as f64 - 4.0, fmt.e_max as f64 + 1.0);
        let m = rng.uniform_in(1.0, 2.0);
        let s = if rng.uniform() < 0.5 { -1.0 } else { 1.0 };
        vals.push(s * m * (2.0f64).powf(e.min(300.0).max(-300.0)));
    }
    vals.extend([
        fmt.x_min(),
        -fmt.x_min(),
        fmt.x_min_sub(),
        fmt.x_max(),
        -fmt.x_max(),
        fmt.x_max() * 1.5,
        0.0,
        1.0,
        -1.0,
    ]);
    vals
}

#[test]
fn prop_floor_ceil_sandwich_and_membership() {
    for fmt in FORMATS {
        for x in gen_values(&fmt, 3000, 1) {
            let (lo, hi) = fmt.floor_ceil(x);
            assert!(lo <= x && x <= hi, "{}: sandwich fails at {x}: [{lo},{hi}]", fmt.name());
            for v in [lo, hi] {
                assert!(
                    v.is_infinite() || fmt.contains(v),
                    "{}: neighbor {v} of {x} not in format",
                    fmt.name()
                );
            }
        }
    }
}

#[test]
fn prop_round_returns_a_neighbor() {
    let mut rng = Rng::new(2);
    for fmt in FORMATS {
        for mode in MODES {
            for x in gen_values(&fmt, 600, 3) {
                let y = round(&fmt, mode, x, &mut rng);
                let (lo, hi) = fmt.floor_ceil(x);
                let sat_lo = lo.max(-fmt.x_max());
                let sat_hi = hi.min(fmt.x_max());
                let ok = y == lo || y == hi || y == sat_lo || y == sat_hi;
                assert!(ok, "{} {:?}: round({x}) = {y}, neighbors [{lo},{hi}]", fmt.name(), mode);
            }
        }
    }
}

#[test]
fn prop_deterministic_modes_are_monotone() {
    // x <= y  =>  fl(x) <= fl(y) for all deterministic modes.
    for fmt in FORMATS {
        let mut vals = gen_values(&fmt, 2000, 4);
        vals.retain(|v| v.is_finite());
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut rng = Rng::new(0);
        for mode in [
            Rounding::RoundNearestEven,
            Rounding::RoundDown,
            Rounding::RoundUp,
            Rounding::RoundTowardZero,
        ] {
            let rounded: Vec<f64> = vals.iter().map(|&v| round(&fmt, mode, v, &mut rng)).collect();
            for w in rounded.windows(2) {
                assert!(w[0] <= w[1], "{} {:?}: monotonicity violated", fmt.name(), mode);
            }
        }
    }
}

#[test]
fn prop_rounding_preserves_sign_and_zero() {
    let mut rng = Rng::new(5);
    for fmt in FORMATS {
        for mode in MODES {
            for x in gen_values(&fmt, 500, 6) {
                let y = round(&fmt, mode, x, &mut rng);
                if x > 0.0 {
                    assert!(y >= 0.0, "{:?}: sign flip at {x} -> {y}", mode);
                } else if x < 0.0 {
                    assert!(y <= 0.0, "{:?}: sign flip at {x} -> {y}", mode);
                } else {
                    assert_eq!(y, 0.0);
                }
            }
        }
    }
}

#[test]
fn prop_idempotence_on_representables() {
    let mut rng = Rng::new(7);
    for fmt in FORMATS {
        for mode in MODES {
            for x in gen_values(&fmt, 400, 8) {
                let y = round(&fmt, mode, x, &mut rng);
                if y.is_finite() {
                    let z = round(&fmt, mode, y, &mut rng);
                    assert_eq!(y, z, "{} {:?}: not idempotent at {x}", fmt.name(), mode);
                }
            }
        }
    }
}

#[test]
fn prop_su_pr_are_strict_inverses() {
    for fmt in FORMATS {
        let mut rng = Rng::new(9);
        for x in gen_values(&fmt, 1500, 10) {
            let y = round(&fmt, Rounding::RoundNearestEven, x, &mut rng);
            if !y.is_finite() || y.abs() >= fmt.x_max() {
                continue;
            }
            let su = fmt.successor(y);
            assert!(su > y);
            if su.is_finite() {
                assert_eq!(fmt.predecessor(su), y, "{}: pr(su({y})) != {y}", fmt.name());
            }
            let pr = fmt.predecessor(y);
            assert!(pr < y);
            if pr.is_finite() {
                assert_eq!(fmt.successor(pr), y, "{}: su(pr({y})) != {y}", fmt.name());
            }
        }
    }
}

/// Monte-Carlo false-failure bound for this file's empirical-mean tests:
/// each draw lies in one gap `[⌊x⌋, ⌈x⌉]`, so by Hoeffding every
/// assertion fails spuriously with probability at most `MC_P_FAIL`. The
/// value is the `p` whose Hoeffding half-width matches the historic
/// `5·gap/√n` tolerance (`ln(2/p) ≈ 50`), keeping the fixed-seed
/// outcomes unchanged while making the bound explicit (docs/testing.md).
const MC_P_FAIL: f64 = 3.8e-22;

#[test]
fn prop_sr_empirical_mean_matches_closed_form() {
    // Statistical: for random (but fixed-seed) x, the sample mean over
    // 4000 draws matches the closed-form expectation within the Hoeffding
    // band; spurious failure probability ≤ MC_P_FAIL per case.
    let fmt = FpFormat::BINARY8;
    let mut seed_rng = Rng::new(11);
    for mode in [Rounding::Sr, Rounding::SrEps(0.2), Rounding::SignedSrEps(0.2)] {
        for _ in 0..25 {
            let x = seed_rng.uniform_in(-30.0, 30.0);
            let v = seed_rng.uniform_in(-1.0, 1.0);
            let (lo, hi) = fmt.floor_ceil(x);
            if lo == hi {
                continue;
            }
            let n = 4000;
            let mut rng = Rng::new(12);
            let mean: f64 =
                (0..n).map(|_| round_with(&fmt, mode, x, v, &mut rng)).sum::<f64>() / n as f64;
            let want = expected_round(&fmt, mode, x, v);
            let tol = lpgd::util::stats::hoeffding_halfwidth(hi - lo, n, MC_P_FAIL);
            assert!(
                (mean - want).abs() < tol,
                "{:?} x={x}: mean {mean} vs E {want} (tol {tol})",
                mode
            );
        }
    }
}

#[test]
fn prop_expected_error_bounds() {
    // |E[fl(x)] - x| <= gap for all schemes; for SR it is 0; for SR_eps it
    // is <= eps*gap + (RN part); always finite.
    let fmt = FpFormat::BFLOAT16;
    let mut rng = Rng::new(13);
    for _ in 0..4000 {
        let x = rng.normal() * 100.0;
        let (lo, hi) = fmt.floor_ceil(x);
        let gap = hi - lo;
        for mode in [Rounding::Sr, Rounding::SrEps(0.4), Rounding::SignedSrEps(0.4)] {
            let e = expected_round(&fmt, mode, x, -x);
            assert!((e - x).abs() <= gap + 1e-18, "{:?}: |bias| > gap at {x}", mode);
        }
        assert!((expected_round(&fmt, Rounding::Sr, x, x) - x).abs() < 1e-12 * x.abs().max(1e-30));
    }
}

/// Bias satellite: `expected_round` must match a Monte-Carlo estimate of
/// `round` for SR and SRε *on the boundary cases* where the closed form is
/// easiest to get wrong — subnormals, exact grid points, and halfway points
/// of both the subnormal and a coarse normal binade.
#[test]
fn prop_expected_round_matches_monte_carlo_on_boundaries() {
    let fmt = FpFormat::BINARY8;
    let q = fmt.x_min_sub(); // smallest subnormal = subnormal spacing, 2^-16
    let cases: Vec<f64> = vec![
        // Subnormal interior and halfway points (both signs).
        0.4 * q,
        0.5 * q,
        -0.5 * q,
        2.5 * q,
        -3.75 * q,
        // Just below the normal threshold and just above it.
        fmt.x_min() - 0.25 * q,
        fmt.x_min() + 0.3 * fmt.spacing_at(fmt.x_min()),
        // Exact grid points: every scheme must be the identity, surely.
        q,
        -2.0 * q,
        fmt.x_min(),
        1.0,
        -1.25,
        1024.0,
        fmt.x_max(),
        // Halfway points of normal binades, fine and coarse.
        1.125,
        -1.125,
        1024.0 + 128.0,
    ];
    let n = 60_000;
    for mode in [Rounding::Sr, Rounding::SrEps(0.25), Rounding::SrEps(0.5)] {
        let mut rng = Rng::new(2024);
        for &x in &cases {
            let want = expected_round(&fmt, mode, x, x);
            let (lo, hi) = fmt.floor_ceil(x);
            if lo == hi {
                // x ∈ F: fixed point of the scheme, in expectation and surely.
                assert_eq!(want, x, "{mode:?}: E[fl(x)] must be x at grid point {x}");
                for _ in 0..16 {
                    assert_eq!(round(&fmt, mode, x, &mut rng), x);
                }
                continue;
            }
            let mean: f64 =
                (0..n).map(|_| round(&fmt, mode, x, &mut rng)).sum::<f64>() / n as f64;
            // Hoeffding band for a two-point distribution on [lo, hi]:
            // spurious failure probability ≤ MC_P_FAIL per case.
            let tol = lpgd::util::stats::hoeffding_halfwidth(hi - lo, n, MC_P_FAIL);
            assert!(
                (mean - want).abs() < tol,
                "{mode:?} x={x:e}: Monte-Carlo {mean:e} vs closed form {want:e} (tol {tol:e})"
            );
        }
    }
}

/// Bit-kernel satellite: exhaustive equivalence sweep of the bit-level
/// `floor_ceil` / `contains` / `successor` / `predecessor` against the
/// retained float-arithmetic oracle (`fp::format::reference`) over **every
/// representable binary8 value** — plus every halfway point between
/// neighbors, the subnormal grid, ±overflow magnitudes, ±∞ and ±0 — rounded
/// into all four narrow formats.
#[test]
fn prop_bit_kernels_match_reference_exhaustive() {
    use lpgd::fp::format::{pow2, reference};

    // All nonnegative binary8 grid points (subnormals + normals), sorted.
    let b8 = FpFormat::BINARY8;
    let mut grid: Vec<f64> = vec![0.0];
    let q = b8.x_min_sub();
    for m in 1..(1u64 << (b8.sig_bits - 1)) {
        grid.push(m as f64 * q);
    }
    for e in b8.e_min..=b8.e_max {
        let ulp = pow2(e - b8.sig_bits as i32 + 1);
        for m in (1u64 << (b8.sig_bits - 1))..(1u64 << b8.sig_bits) {
            grid.push(m as f64 * ulp); // exact: m < 2^s, ulp a power of two
        }
    }
    // Inputs: the grid, every halfway point, overflow, specials; both signs.
    let mut inputs: Vec<f64> = grid.clone();
    for w in grid.windows(2) {
        inputs.push((w[0] + w[1]) / 2.0); // exact midpoint
    }
    inputs.extend([b8.x_max() * 1.25, b8.x_max() * 64.0, f64::INFINITY]);
    let negs: Vec<f64> = inputs.iter().map(|&v| -v).collect();
    inputs.extend(negs);

    for fmt in FORMATS {
        for &x in &inputs {
            let want = fmt.floor_ceil(x);
            let got = reference::floor_ceil(&fmt, x);
            assert_eq!(want, got, "{} floor_ceil({x:e})", fmt.name());
            assert_eq!(
                fmt.contains(x),
                reference::contains(&fmt, x),
                "{} contains({x:e})",
                fmt.name()
            );
        }
        // Strict neighbors on every in-format grid point (both signs).
        for &g in &grid {
            for &x in &[g, -g] {
                if !fmt.contains(x) || x.abs() >= fmt.x_max() {
                    continue;
                }
                assert_eq!(
                    fmt.successor(x),
                    reference::successor(&fmt, x),
                    "{} successor({x:e})",
                    fmt.name()
                );
                assert_eq!(
                    fmt.predecessor(x),
                    reference::predecessor(&fmt, x),
                    "{} predecessor({x:e})",
                    fmt.name()
                );
            }
        }
    }
}

/// Fixed-point satellite: exhaustive small-grid oracle sweep. Every
/// representable Q2.3 value (all 64 stored integers), every halfway point
/// between neighbors, quarter-points, out-of-range magnitudes, ±∞ and ±0
/// are checked against a *naive* f64 reference built by materializing the
/// entire grid as a sorted vector and scanning for neighbors — fully
/// independent of the production integer-quantization path.
#[test]
fn prop_fixed_point_small_grid_matches_naive_oracle() {
    use lpgd::fp::{FixedPoint, NumberGrid, RoundPlan};

    for fx in [FixedPoint::q(2, 3), FixedPoint::uq(2, 3)] {
        let d = fx.delta();
        // Materialize the whole grid: k_min..=k_max stored integers.
        let (k_min, k_max) = if fx.signed {
            (-(1i64 << (fx.word_bits - 1)), (1i64 << (fx.word_bits - 1)) - 1)
        } else {
            (0, (1i64 << fx.word_bits) - 1)
        };
        let grid: Vec<f64> = (k_min..=k_max).map(|k| k as f64 * d).collect();
        assert_eq!(grid[0], NumberGrid::min_value(&fx));
        assert_eq!(*grid.last().unwrap(), NumberGrid::max_value(&fx));

        // Naive oracle: scan the sorted grid for the neighbor pair.
        let oracle_floor_ceil = |x: f64| -> (f64, f64) {
            let lo = grid.iter().rev().find(|&&g| g <= x).copied();
            let hi = grid.iter().find(|&&g| g >= x).copied();
            (lo.unwrap_or(f64::NEG_INFINITY), hi.unwrap_or(f64::INFINITY))
        };

        // Inputs: the grid, halfway and quarter points of every gap,
        // out-of-range magnitudes and the specials.
        let mut inputs: Vec<f64> = grid.clone();
        for w in grid.windows(2) {
            inputs.push((w[0] + w[1]) / 2.0); // exact midpoint
            inputs.push(w[0] + 0.25 * d);
            inputs.push(w[0] + 0.75 * d);
        }
        inputs.extend([
            NumberGrid::max_value(&fx) + 0.4 * d,
            NumberGrid::max_value(&fx) + 10.0,
            NumberGrid::min_value(&fx) - 0.4 * d,
            NumberGrid::min_value(&fx) - 10.0,
            f64::INFINITY,
            f64::NEG_INFINITY,
            0.0,
            -0.0,
        ]);

        for &x in &inputs {
            let want = oracle_floor_ceil(x);
            let got = NumberGrid::floor_ceil(&fx, x);
            assert_eq!(got, want, "{} floor_ceil({x:e})", fx.name());
            let on_grid = grid.contains(&x);
            assert_eq!(NumberGrid::contains(&fx, x), on_grid, "{} contains({x:e})", fx.name());
            assert_eq!(got.0 == got.1, on_grid, "{} degenerate pair iff on grid", fx.name());
        }

        // Strict successor/predecessor against the sorted index.
        for (i, &g) in grid.iter().enumerate() {
            let su = NumberGrid::successor(&fx, g);
            let want_su = grid.get(i + 1).copied().unwrap_or(f64::INFINITY);
            assert_eq!(su, want_su, "{} successor({g})", fx.name());
            let pr = NumberGrid::predecessor(&fx, g);
            let want_pr =
                if i == 0 { f64::NEG_INFINITY } else { grid[i - 1] };
            assert_eq!(pr, want_pr, "{} predecessor({g})", fx.name());
        }

        // Rounding laws on the exhaustive inputs: directed modes pick the
        // oracle side (with saturation), RN picks the nearer side and
        // breaks exact ties toward the even stored integer, and SR outputs
        // are always (saturated) oracle neighbors.
        let plan = RoundPlan::new(fx);
        let mut rng = Rng::new(77);
        let satv = |y: f64| y.clamp(NumberGrid::min_value(&fx), NumberGrid::max_value(&fx));
        for &x in &inputs {
            if x.is_nan() {
                continue;
            }
            let (lo, hi) = oracle_floor_ceil(x);
            let (slo, shi) = (satv(lo), satv(hi));
            let rd = plan.round_with(Rounding::RoundDown, x, x, &mut rng);
            assert_eq!(rd, slo, "{} RD({x:e})", fx.name());
            let ru = plan.round_with(Rounding::RoundUp, x, x, &mut rng);
            assert_eq!(ru, shi, "{} RU({x:e})", fx.name());
            let rz = plan.round_with(Rounding::RoundTowardZero, x, x, &mut rng);
            let rz_want = if x > 0.0 {
                slo
            } else if x < 0.0 {
                shi
            } else {
                0.0
            };
            assert_eq!(rz, rz_want, "{} RZ({x:e})", fx.name());
            let rn = plan.round_with(Rounding::RoundNearestEven, x, x, &mut rng);
            if slo == shi {
                assert_eq!(rn, slo, "{} RN({x:e}) saturation", fx.name());
            } else if x - lo < hi - x {
                assert_eq!(rn, lo, "{} RN({x:e}) lower", fx.name());
            } else if hi - x < x - lo {
                assert_eq!(rn, hi, "{} RN({x:e}) upper", fx.name());
            } else {
                let k_lo = (lo / d).round() as i64;
                let want = if k_lo % 2 == 0 { lo } else { hi };
                assert_eq!(rn, want, "{} RN({x:e}) tie-to-even-k", fx.name());
            }
            for _ in 0..4 {
                let sr = plan.round_with(Rounding::Sr, x, x, &mut rng);
                assert!(sr == slo || sr == shi, "{} SR({x:e}) -> {sr}", fx.name());
            }
        }
    }
}

#[test]
fn prop_nan_and_inf_handling() {
    let mut rng = Rng::new(14);
    for fmt in FORMATS {
        for mode in MODES {
            assert!(round(&fmt, mode, f64::NAN, &mut rng).is_nan());
            let pi = round(&fmt, mode, f64::INFINITY, &mut rng);
            assert!(pi == f64::INFINITY || pi == fmt.x_max());
            let ni = round(&fmt, mode, f64::NEG_INFINITY, &mut rng);
            assert!(ni == f64::NEG_INFINITY || ni == -fmt.x_max());
        }
    }
}

/// Robustness satellite: a NaN input propagates as NaN through every
/// scheme in the registry — scalar and fused slice kernels, float and
/// fixed-point grids — without panicking, and without disturbing finite
/// neighbors in the same slice. The health layer counts NaN productions,
/// so the kernels underneath must survive them.
#[test]
fn prop_nan_propagates_through_every_registered_scheme() {
    use lpgd::fp::{FixedPoint, Grid, RoundPlan, SchemeRegistry};

    let grids: [Grid; 3] =
        [FpFormat::BINARY8.into(), FpFormat::BFLOAT16.into(), FixedPoint::q(3, 8).into()];
    for (name, _aliases, _summary) in SchemeRegistry::entries() {
        // Parameterized families are listed as "fam[:eps]"; instantiate
        // them with a representative eps.
        let spec = match name.split_once("[:eps]") {
            Some((base, _)) => format!("{base}:0.25"),
            None => name.clone(),
        };
        let scheme = SchemeRegistry::lookup(&spec).expect("registry entry must resolve");
        for &grid in &grids {
            let plan = RoundPlan::new(grid);
            let mut rng = Rng::new(21);
            let y = plan.round_scheme(scheme, f64::NAN, &mut rng);
            assert!(y.is_nan(), "{spec} on {}: NaN -> {y}", grid.label());
            // Slice kernel: NaN embedded among finite values must come out
            // NaN with the finite entries still rounded onto the grid.
            let mut xs = [1.0, f64::NAN, -0.5, 0.25];
            let vs = xs;
            plan.round_slice_scheme_with(scheme, &mut xs, &vs, &mut rng);
            assert!(xs[1].is_nan(), "{spec} on {}: slice NaN lost", grid.label());
            for (j, &x) in xs.iter().enumerate() {
                if j != 1 {
                    assert!(x.is_finite(), "{spec} on {}: neighbor {j} became {x}", grid.label());
                }
            }
        }
    }
}

#[test]
fn prop_gd_iterate_always_in_format() {
    // Random diagonal quadratics, random schemes: the engine's iterate is
    // exactly representable after every step.
    use lpgd::gd::engine::{GdConfig, GdEngine};
    use lpgd::problems::Quadratic;
    let mut rng = Rng::new(15);
    for trial in 0..12 {
        let n = 1 + (trial % 5);
        let diag: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.1, 3.0)).collect();
        let xstar: Vec<f64> = (0..n).map(|_| rng.uniform_in(-100.0, 100.0)).collect();
        let x0: Vec<f64> = (0..n).map(|_| rng.uniform_in(-100.0, 100.0)).collect();
        let p = Quadratic::diagonal(diag, xstar);
        let mode = MODES[trial % MODES.len()];
        let fmt = FORMATS[trial % 3];
        let mut cfg = GdConfig::new(fmt, mode, 0.05, 25);
        cfg.seed = trial as u64;
        let mut e = GdEngine::new(cfg, &p, &x0);
        for _ in 0..25 {
            e.step();
            for &xi in &e.x {
                assert!(fmt.contains(xi) || xi.is_infinite(), "{:?} {}: {xi}", mode, fmt.name());
            }
        }
    }
}
