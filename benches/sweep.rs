// Bench: the sharded experiment sweep — the coordinator's scheduler fanning
// (rounding-mode × repetition) GD cells across the worker pool. Reports the
// serial (jobs=1) and multi-core (jobs=0 → all cores) wall clock for the
// same cell grid, verifies the merged results are bit-identical, and prints
// the speedup (the acceptance metric for the sharded coordinator).
//
// Run: `cargo bench --bench sweep`

include!("harness.rs");

use lpgd::coordinator::scheduler::{available_jobs, cell_stream, run_indexed};
use lpgd::fp::{FpFormat, Rng, Scheme};
use lpgd::gd::engine::{GdConfig, GdEngine, PolicyMap};
use lpgd::problems::Quadratic;

fn main() {
    warn_if_hand_projected("sweep");
    let n = 200;
    let steps = 300;
    let reps = 8u64;
    let (p, x0, _) = Quadratic::setting2(n, 0);
    let modes = [
        Scheme::sr(),
        Scheme::sr_eps(0.1),
        Scheme::sr_eps(0.4),
        Scheme::signed_sr_eps(0.1),
    ];
    let cells: Vec<(usize, u64)> =
        (0..modes.len()).flat_map(|m| (0..reps).map(move |r| (m, r))).collect();
    let root_seed = 7u64;

    let sweep = |jobs: usize| -> Vec<f64> {
        run_indexed(jobs, cells.len(), |k| {
            let (m, r) = cells[k];
            let mode = modes[m];
            let schemes = PolicyMap::sites(Scheme::sr(), Scheme::sr(), mode);
            let mut cfg = GdConfig::new(FpFormat::BFLOAT16, schemes, 1.0 / n as f64, steps);
            cfg.rng = Some(Rng::new(root_seed).split(cell_stream("sweep", &mode.label(), r)));
            let mut e = GdEngine::new(cfg, &p, &x0);
            e.run(None).final_f()
        })
    };

    println!(
        "-- sharded sweep: {} cells (dense quad n={n}, {steps} steps), {} cores --",
        cells.len(),
        available_jobs()
    );
    let serial = bench("sweep jobs=1 (serial)", cells.len() as u64, || {
        std::hint::black_box(sweep(1));
    });
    let parallel = bench("sweep jobs=0 (all cores)", cells.len() as u64, || {
        std::hint::black_box(sweep(0));
    });
    let s = report_speedup(&serial, &parallel);

    // Determinism spot-check on the real results (not just the bench body).
    let a = sweep(1);
    let b = sweep(0);
    assert_eq!(a, b, "jobs=1 and jobs=0 merged results must be bit-identical");
    println!("determinism OK: {} cells bit-identical across job counts", a.len());

    write_bench_json(
        "sweep",
        &[serial, parallel],
        &[("sweep_serial_vs_all_cores".into(), s)],
    )
    .expect("writing BENCH_sweep.json");
}
