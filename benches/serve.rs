// Bench: the experiment service's request path — registry hits (the hot
// path the service exists for) vs cold misses that fan the computation out
// across the scheduler. Drives `ExperimentService::handle` in-process with
// synthetic requests, so the numbers measure dispatch + registry + compute
// without socket noise, and reports the hit/miss ratio (the acceptance
// metric: serving from the registry must be orders of magnitude cheaper
// than recomputing).
//
// Run: `cargo bench --bench serve`

include!("harness.rs");

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use lpgd::registry::ResultStore;
use lpgd::serve::http::Request;
use lpgd::serve::ExperimentService;

fn post_run(seed: u64) -> Request {
    Request {
        method: "POST".to_string(),
        path: "/v1/run".to_string(),
        body: format!(
            r#"{{"problem":{{"kind":"quadratic1","dim":64}},"grid":"bfloat16",
                "stepsize":0.05,"steps":200,"seed":{seed},"reps":1}}"#
        )
        .into_bytes(),
    }
}

fn main() {
    warn_if_hand_projected("serve");
    let dir = std::env::temp_dir().join(format!("lpgd_bench_serve_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(ResultStore::open(&dir).expect("open bench registry"));
    let service = ExperimentService::new(store, 4096, 1);

    println!("-- serve: POST /v1/run, quadratic1 n=64, 200 steps, 1 rep --");

    // Cold path: every iteration a fresh seed, so every request computes
    // its cell and writes it back.
    let next_seed = AtomicU64::new(0);
    let miss = bench("run miss (compute + write-back)", 1, || {
        let req = post_run(next_seed.fetch_add(1, Ordering::Relaxed));
        let resp = service.handle(&req);
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        std::hint::black_box(resp.body.len());
    });

    // Hot path: one warmed spec answered from the registry every time.
    let warm = post_run(999_999_999);
    assert_eq!(service.handle(&warm).status, 200);
    let hit = bench("run hit (registry-served)", 1, || {
        let resp = service.handle(&warm);
        assert_eq!(resp.status, 200);
        std::hint::black_box(resp.body.len());
    });

    // Stats never touch the registry log — a floor for pure dispatch.
    let stats_req = Request {
        method: "GET".to_string(),
        path: "/v1/stats".to_string(),
        body: Vec::new(),
    };
    let stats = bench("stats (dispatch floor)", 1, || {
        std::hint::black_box(service.handle(&stats_req).body.len());
    });

    let ratio = report_speedup(&miss, &hit);
    for r in [&miss, &hit, &stats] {
        println!(
            "  {:<40} {:>10.0} req/s (median)",
            r.name,
            1e9 / r.median_ns
        );
    }

    write_bench_json(
        "serve",
        &[miss, hit, stats],
        &[("serve_hit_vs_miss".into(), ratio)],
    )
    .expect("writing BENCH_serve.json");
    let _ = std::fs::remove_dir_all(&dir);
}
