// Bench: wall-clock of regenerating every paper table/figure at the quick
// profile — the "one bench per table/figure" harness. Run with defaults via
// `lpgd reproduce <id>` for full fidelity.

include!("harness.rs");

use lpgd::coordinator::experiments::{run_experiment, ExpCtx, EXPERIMENTS};

fn main() {
    let mut ctx = ExpCtx::quick();
    ctx.out_dir = std::env::temp_dir().join("lpgd_bench_figures").to_string_lossy().into_owned();
    println!("-- per-figure regeneration cost (quick profile) --");
    for (id, _) in EXPERIMENTS {
        bench(&format!("reproduce {id}"), 0, || {
            run_experiment(id, &ctx).expect("experiment failed");
        });
    }
}
