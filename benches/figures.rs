// Bench: wall-clock of regenerating every paper table/figure at the quick
// profile — the "one bench per table/figure" harness. Run with defaults via
// `lpgd reproduce <id>` for full fidelity. Measured serially (jobs = 1) so
// per-figure costs are comparable; the multi-core sweep speedup is measured
// by `benches/sweep.rs`.

include!("harness.rs");

use lpgd::coordinator::experiments::{list_experiments, run_experiment, ExpCtx};

fn main() {
    let mut ctx = ExpCtx::quick();
    ctx.jobs = 1;
    ctx.out_dir = std::env::temp_dir().join("lpgd_bench_figures").to_string_lossy().into_owned();
    println!("-- per-figure regeneration cost (quick profile, serial) --");
    for (id, _) in list_experiments() {
        bench(&format!("reproduce {id}"), 0, || {
            run_experiment(id, &ctx).expect("experiment failed");
        });
    }
}
