// Bench: fused optimizer-update cost per step — the optimizer zoo (plain
// GD vs momentum vs Nesterov vs Adam) on the same rounded quadratic, the
// per-tensor policy-binding overhead (master weights on binary64, fp32
// momentum buffer), and the LR-schedule overhead (constant vs inverse-time
// decay). The plain-GD row doubles as the refactor's regression sentinel:
// the trait-driven engine must price one GD step like the pre-trait one
// (compare against BENCH_gd_step.json's "gd_step quad diag n=1000").
// Emits BENCH_opt_step.json (schema v1; refresh with scripts/bench.sh).

include!("harness.rs");

use lpgd::fp::{FpFormat, Scheme};
use lpgd::gd::engine::{GdConfig, GdEngine, PolicyMap, TensorPolicy};
use lpgd::gd::optimizer::{LrSchedule, OptimizerSpec};
use lpgd::problems::Quadratic;

fn main() {
    warn_if_hand_projected("opt_step");
    let mut results: Vec<BenchResult> = Vec::new();
    let mut speedups: Vec<(String, f64)> = Vec::new();
    let (p, x0, t) = Quadratic::setting1(1000);
    let schemes = PolicyMap::uniform(Scheme::sr());

    println!("-- optimizer zoo: one rounded step, quad diag n=1000, bfloat16 SR --");
    let mut gd_row: Option<BenchResult> = None;
    for (name, opt) in [
        ("gd", OptimizerSpec::Gd),
        ("momentum", OptimizerSpec::Momentum { beta: 0.9 }),
        ("nesterov", OptimizerSpec::Nesterov { beta: 0.9 }),
        ("adam", OptimizerSpec::Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8 }),
    ] {
        let mut cfg = GdConfig::new(FpFormat::BFLOAT16, schemes, t, 1);
        cfg.seed = 0;
        cfg.optimizer = opt;
        let mut e = GdEngine::new(cfg, &p, &x0);
        let r = bench(&format!("opt_step {name} quad diag n=1000 bf16"), 1000, || {
            e.step();
        });
        match &gd_row {
            None => gd_row = Some(r),
            Some(gd) => {
                // Cost of the stateful optimizer relative to plain GD
                // (ratio > 1 = that much slower per step).
                let rel = r.min_ns / gd.min_ns;
                println!("relative cost: {name} = {rel:.2}x of plain gd");
                speedups.push((format!("opt_step_{name}_cost_vs_gd"), rel));
                results.push(r);
            }
        }
    }
    results.insert(0, gd_row.expect("gd row benched first"));

    println!("-- policy bindings: momentum with master weights / fp32 m --");
    for (name, pol) in [
        ("unbound", schemes),
        (
            "w=rn@binary64",
            PolicyMap::uniform(Scheme::sr())
                .with_weights(TensorPolicy::new(Scheme::rn()).on(FpFormat::BINARY64)),
        ),
        (
            "m=rn@binary32",
            PolicyMap::uniform(Scheme::sr())
                .with_m(TensorPolicy::new(Scheme::rn()).on(FpFormat::BINARY32)),
        ),
    ] {
        let mut cfg = GdConfig::new(FpFormat::BFLOAT16, pol, t, 1);
        cfg.seed = 0;
        cfg.optimizer = OptimizerSpec::Momentum { beta: 0.9 };
        let mut e = GdEngine::new(cfg, &p, &x0);
        results.push(bench(&format!("opt_step momentum {name} n=1000 bf16"), 1000, || {
            e.step();
        }));
    }

    println!("-- LR schedules: constant vs inverse-time decay (momentum) --");
    for (name, lr) in [
        ("const", LrSchedule::Constant),
        ("inv:0.01", LrSchedule::InvTime { rate: 0.01 }),
    ] {
        let mut cfg = GdConfig::new(FpFormat::BFLOAT16, schemes, t, 1);
        cfg.seed = 0;
        cfg.optimizer = OptimizerSpec::Momentum { beta: 0.9 };
        cfg.lr = lr;
        let mut e = GdEngine::new(cfg, &p, &x0);
        results.push(bench(&format!("opt_step momentum lr={name} n=1000 bf16"), 1000, || {
            e.step();
        }));
    }

    write_bench_json("opt_step", &results, &speedups).expect("writing BENCH_opt_step.json");
}
