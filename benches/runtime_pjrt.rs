// Bench: PJRT-artifact hot path vs the Rust-native engine — the
// rust-native-vs-artifact ablation called out in DESIGN.md §7.

include!("harness.rs");

use lpgd::data::synth;
use lpgd::fp::{round_slice, FpFormat, Rng, Rounding};
use lpgd::problems::{Mlr, Problem};
use lpgd::runtime::{Arg, Runtime, MLR_SPEC, QUANTIZE_SPEC};

fn main() {
    let mut rt = match Runtime::cpu("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipping PJRT benches (run `make artifacts`): {e}");
            return;
        }
    };
    println!("platform: {}", rt.platform());

    println!("-- quantizer: PJRT artifact vs Rust substrate ({} elems) --", QUANTIZE_SPEC.params);
    {
        let n = QUANTIZE_SPEC.params;
        let mut rng = Rng::new(0);
        let x: Vec<f64> = (0..n).map(|_| rng.normal() * 2.0).collect();
        let u: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
        {
            let exe = rt.load(QUANTIZE_SPEC.file).unwrap();
            bench("quantize via PJRT (incl. marshal)", n as u64, || {
                let out = exe
                    .run_f32(&[
                        Arg::f32_from_f64(&x, &[n as i64]),
                        Arg::f32_from_f64(&u, &[n as i64]),
                        Arg::f32_from_f64(&x, &[n as i64]),
                        Arg::ScalarI32(1),
                        Arg::ScalarF32(0.0),
                    ])
                    .unwrap();
                std::hint::black_box(&out[0]);
            });
        }
        let mut buf = x.clone();
        let mut r2 = Rng::new(1);
        bench("quantize via Rust substrate", n as u64, || {
            buf.copy_from_slice(&x);
            round_slice(&FpFormat::BINARY8, Rounding::Sr, &mut buf, &mut r2);
        });
    }

    println!("-- MLR train step: PJRT artifact vs Rust engine (batch 256) --");
    {
        let spec = MLR_SPEC;
        let n = spec.batch;
        let data = synth::generate(n, 14, 3);
        let mut xb = Vec::with_capacity(n * spec.features);
        let mut yb = vec![0.0f64; n * spec.classes];
        for i in 0..n {
            xb.extend_from_slice(data.row(i));
            yb[i * spec.classes + data.labels[i] as usize] = 1.0;
        }
        let params = vec![0.0f64; spec.params];
        let mut rng = Rng::new(4);
        let uni: Vec<f64> = (0..3 * spec.params).map(|_| rng.uniform()).collect();
        {
            let exe = rt.load(spec.file).unwrap();
            bench("mlr_step via PJRT (incl. marshal)", (n * spec.features * spec.classes) as u64, || {
                let out = exe
                    .run_f32(&[
                        Arg::f32_from_f64(&params, &[spec.params as i64]),
                        Arg::f32_from_f64(&xb, &[n as i64, spec.features as i64]),
                        Arg::f32_from_f64(&yb, &[n as i64, spec.classes as i64]),
                        Arg::f32_from_f64(&uni, &[3, spec.params as i64]),
                        Arg::ScalarF32(0.5),
                        Arg::ScalarF32(0.0),
                        Arg::I32(vec![1, 1, 1], vec![3]),
                    ])
                    .unwrap();
                std::hint::black_box(&out[0]);
            });
        }
        // Rust-native equivalent: one full-batch gradient + rounded update.
        let p = Mlr::new(data, spec.classes);
        let x0 = vec![0.0; p.dim()];
        let mut cfg = lpgd::gd::engine::GdConfig::new(FpFormat::BINARY8, Rounding::Sr, 0.5, 1);
        cfg.seed = 0;
        let mut e = lpgd::gd::engine::GdEngine::new(cfg, &p, &x0);
        bench("mlr_step via Rust engine", (n * spec.features * spec.classes) as u64, || {
            e.step();
        });
    }
}
