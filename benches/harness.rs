// Minimal bench harness (criterion is not vendored in this offline image):
// warmup + timed iterations, reporting mean/min ns per op and throughput.
// Used by every bench target via `include!`.

use std::time::Instant;

/// One benchmark's timing summary.
pub struct BenchResult {
    /// Label printed next to the numbers.
    pub name: String,
    /// How many timed iterations ran.
    pub iters: u32,
    /// Mean wall-clock nanoseconds per iteration.
    pub mean_ns: f64,
    /// Fastest iteration in nanoseconds (least noisy on a busy machine).
    pub min_ns: f64,
}

/// Time `f` (which should perform `elems` logical elements of work) until
/// ~0.5 s of samples or `max_iters`, whichever first.
pub fn bench<F: FnMut()>(name: &str, elems: u64, mut f: F) -> BenchResult {
    // Warmup.
    for _ in 0..3 {
        f();
    }
    let mut times = Vec::new();
    let budget = std::time::Duration::from_millis(500);
    let started = Instant::now();
    while started.elapsed() < budget && times.len() < 1000 {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult { name: name.to_string(), iters: times.len() as u32, mean_ns: mean, min_ns: min };
    let throughput = if elems > 0 {
        format!("  {:>9.2} Melem/s", elems as f64 / (mean / 1e9) / 1e6)
    } else {
        String::new()
    };
    println!(
        "{:<44} {:>12.0} ns/iter (min {:>12.0}) x{:<4}{}",
        r.name, r.mean_ns, r.min_ns, r.iters, throughput
    );
    r
}

/// Wall-clock speedup of `fast` relative to `base`, on best-iteration
/// times, and a one-line report. Used by `benches/sweep.rs` to show the
/// multi-core gain of the sharded coordinator over the serial path.
#[allow(dead_code)]
pub fn report_speedup(base: &BenchResult, fast: &BenchResult) -> f64 {
    let s = base.min_ns / fast.min_ns;
    println!("speedup: {} -> {}: {s:.2}x", base.name, fast.name);
    s
}
