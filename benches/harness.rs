// Minimal bench harness (criterion is not vendored in this offline image):
// warmup + timed iterations, reporting mean/median/p10/p90/min ns per op and
// throughput, plus a machine-readable `BENCH_<name>.json` emitter so the
// perf trajectory is tracked across PRs (refreshed by `scripts/bench.sh`).
// Used by every bench target via `include!`.

use std::time::Instant;

/// One benchmark's timing summary.
pub struct BenchResult {
    /// Label printed next to the numbers.
    pub name: String,
    /// How many timed iterations ran.
    pub iters: u32,
    /// Mean wall-clock nanoseconds per iteration.
    pub mean_ns: f64,
    /// Median nanoseconds per iteration (robust central tendency).
    pub median_ns: f64,
    /// 10th-percentile nanoseconds per iteration.
    pub p10_ns: f64,
    /// 90th-percentile nanoseconds per iteration.
    pub p90_ns: f64,
    /// Fastest iteration in nanoseconds (least noisy on a busy machine).
    pub min_ns: f64,
    /// Logical elements of work performed per iteration (0 = unscaled).
    pub elems: u64,
}

impl BenchResult {
    /// Elements per second at the median iteration time (0 when unscaled).
    pub fn elems_per_sec(&self) -> f64 {
        if self.elems > 0 && self.median_ns > 0.0 {
            self.elems as f64 / (self.median_ns / 1e9)
        } else {
            0.0
        }
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Is the reduced-iteration smoke profile requested? `BENCH_SMOKE=1` (any
/// non-empty value other than `0`) cuts the per-bench budget ~10× so the
/// CI `bench-smoke` job can exercise every bench target and still upload
/// fresh `BENCH_*.json` artifacts in minutes. Smoke numbers are noisier —
/// they validate the pipeline and give a coarse trajectory, not a
/// publishable measurement.
pub fn smoke_mode() -> bool {
    std::env::var("BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Time `f` (which should perform `elems` logical elements of work) until
/// ~0.5 s of samples (50 ms under `BENCH_SMOKE=1`) or the iteration cap,
/// whichever first.
pub fn bench<F: FnMut()>(name: &str, elems: u64, mut f: F) -> BenchResult {
    let smoke = smoke_mode();
    let (warmup, budget_ms, max_iters) = if smoke { (1, 50, 40) } else { (3, 500, 1000) };
    // Warmup.
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::new();
    let budget = std::time::Duration::from_millis(budget_ms);
    let started = Instant::now();
    while started.elapsed() < budget && times.len() < max_iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut sorted = times.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let r = BenchResult {
        name: name.to_string(),
        iters: times.len() as u32,
        mean_ns: mean,
        median_ns: percentile(&sorted, 0.5),
        p10_ns: percentile(&sorted, 0.1),
        p90_ns: percentile(&sorted, 0.9),
        min_ns: min,
        elems,
    };
    let throughput = if elems > 0 {
        format!("  {:>9.2} Melem/s", r.elems_per_sec() / 1e6)
    } else {
        String::new()
    };
    println!(
        "{:<44} {:>12.0} ns/iter (med {:>12.0}, min {:>12.0}) x{:<4}{}",
        r.name, r.mean_ns, r.median_ns, r.min_ns, r.iters, throughput
    );
    r
}

/// Wall-clock speedup of `fast` relative to `base`, on best-iteration
/// times, and a one-line report. Used by the sweep and gd_step benches to
/// report their acceptance metrics.
#[allow(dead_code)]
pub fn report_speedup(base: &BenchResult, fast: &BenchResult) -> f64 {
    let s = base.min_ns / fast.min_ns;
    println!("speedup: {} -> {}: {s:.2}x", base.name, fast.name);
    s
}

#[allow(dead_code)]
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Staleness guard for the checked-in perf artifacts: the seed repo ships
/// `BENCH_<name>.json` files whose `provenance` field carries the literal
/// `SEED ESTIMATE` marker — hand-projected estimates, not measurements.
/// Each bench calls this at startup so the console run that produces the
/// replacement numbers also announces that the previous file was never
/// measured (scripts/bench.sh performs the same check shell-side, and the
/// CI bench-smoke job fails if the marker survives a bench run). The guard
/// keys on the marker text, not on the presence of a `provenance` field:
/// [`write_bench_json`] stamps every *measured* artifact with an honest
/// provenance line of its own, which must pass silently.
#[allow(dead_code)]
pub fn warn_if_hand_projected(bench: &str) {
    let path = format!("BENCH_{bench}.json");
    if let Ok(body) = std::fs::read_to_string(&path) {
        if body.contains("SEED ESTIMATE") {
            eprintln!(
                "WARNING: {path} carries the hand-projected 'SEED ESTIMATE' marker — its \
                 numbers are seed estimates, not measurements; this run will replace them."
            );
        }
    }
}

/// Write `BENCH_<bench>.json` in the current directory (the workspace root
/// under `cargo bench`): schema v1 with per-result median/p10/p90 ns and
/// elements/sec, plus named derived speedup ratios. The `provenance` field
/// records that the numbers were measured by this run (and under which
/// profile), replacing any `SEED ESTIMATE` marker the seed artifact
/// carried. Returns the path.
#[allow(dead_code)]
pub fn write_bench_json(
    bench: &str,
    results: &[BenchResult],
    speedups: &[(String, f64)],
) -> std::io::Result<String> {
    let path = format!("BENCH_{bench}.json");
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(bench)));
    s.push_str("  \"schema\": 1,\n");
    s.push_str("  \"unit\": \"ns_per_iter\",\n");
    s.push_str(&format!(
        "  \"generated_by\": \"benches/{}.rs via scripts/bench.sh\",\n",
        json_escape(bench)
    ));
    let profile =
        if smoke_mode() { "BENCH_SMOKE reduced-iteration profile" } else { "full profile" };
    s.push_str(&format!(
        "  \"provenance\": \"measured on this machine by benches/{}.rs ({profile})\",\n",
        json_escape(bench)
    ));
    s.push_str("  \"results\": [\n");
    for (i, r) in results.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {:.1}, \"median_ns\": {:.1}, \
             \"p10_ns\": {:.1}, \"p90_ns\": {:.1}, \"min_ns\": {:.1}, \"elems\": {}, \
             \"elems_per_sec\": {:.1}}}{}\n",
            json_escape(&r.name),
            r.iters,
            r.mean_ns,
            r.median_ns,
            r.p10_ns,
            r.p90_ns,
            r.min_ns,
            r.elems,
            r.elems_per_sec(),
            if i + 1 == results.len() { "" } else { "," }
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"speedups\": [\n");
    for (i, (name, x)) in speedups.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"x\": {:.2}}}{}\n",
            json_escape(name),
            x,
            if i + 1 == speedups.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(&path, &s)?;
    println!("wrote {path}");
    Ok(path)
}
