// Bench: full GD-step cost per problem class, the sigma1-model ablation
// (chop-style round-after-op vs strict per-op rounding), and the PR-3
// acceptance metric — the binary8 MLR rounded gradient step through the
// fused kernel layer vs the retained pre-kernel scalar path (target ≥3×).
// Emits BENCH_gd_step.json (schema v1; refresh with scripts/bench.sh).

include!("harness.rs");

use lpgd::data::synth;
use lpgd::fp::{backend_label, set_backend, FixedPoint, FpFormat, LpCtx, Rng, Scheme, SimdChoice};
use lpgd::gd::engine::{GdConfig, GdEngine, GradModel, PolicyMap};
use lpgd::gd::run_lane_batch;
use lpgd::problems::{Mlr, Problem, Quadratic, TwoLayerNn};

fn main() {
    warn_if_hand_projected("gd_step");
    let schemes = PolicyMap::uniform(Scheme::sr());
    let mut results: Vec<BenchResult> = Vec::new();
    let mut speedups: Vec<(String, f64)> = Vec::new();

    println!("-- quadratic Setting I (diag, n=1000): one GD step --");
    {
        let (p, x0, t) = Quadratic::setting1(1000);
        let mut cfg = GdConfig::new(FpFormat::BFLOAT16, schemes, t, 1);
        cfg.seed = 0;
        let mut e = GdEngine::new(cfg, &p, &x0);
        results.push(bench("gd_step quad diag n=1000", 1000, || {
            e.step();
        }));
    }

    println!("-- quadratic Setting II (dense, n=500): one GD step --");
    {
        let (p, x0, t) = Quadratic::setting2(500, 0);
        let mut cfg = GdConfig::new(FpFormat::BFLOAT16, schemes, t, 1);
        cfg.seed = 0;
        let mut e = GdEngine::new(cfg, &p, &x0);
        results.push(bench("gd_step quad dense n=500", 500 * 500, || {
            e.step();
        }));
    }

    println!("-- MLR full-batch epoch (4000x196, C=10) --");
    {
        let data = synth::generate(4000, 14, 0);
        let p = Mlr::new(data, 10);
        let x0 = vec![0.0; p.dim()];
        let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes, 0.5, 1);
        cfg.seed = 0;
        let mut e = GdEngine::new(cfg, &p, &x0);
        results.push(bench("gd_step mlr 4000x196", 4000 * 196 * 10, || {
            e.step();
        }));
    }

    println!("-- NN epoch (1200x196, H=100) --");
    {
        let data = synth::generate(6000, 14, 1).filter_classes(&[3, 8]);
        let p = TwoLayerNn::new(data, 100);
        let x0 = p.init_params(0);
        let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes, 0.09375, 1);
        cfg.seed = 0;
        let mut e = GdEngine::new(cfg, &p, &x0);
        results.push(bench("gd_step nn 1200x196 h=100", 1200 * 196 * 100, || {
            e.step();
        }));
    }

    println!("-- fixed-point lane: one GD step on Q3.8 vs bfloat16 (diag n=1000) --");
    {
        let diag: Vec<f64> = (0..1000).map(|i| 0.05 + 0.95 * i as f64 / 999.0).collect();
        let p = Quadratic::diagonal(diag, vec![0.5; 1000]);
        let x0 = vec![2.0; 1000];
        let mut cfg = GdConfig::new(FixedPoint::q(3, 8), schemes, 0.5, 1);
        cfg.seed = 0;
        let mut ef = GdEngine::new(cfg, &p, &x0);
        let fixed_lane = bench("gd_step quad diag n=1000 q3.8", 1000, || {
            ef.step();
        });
        let mut cfg2 = GdConfig::new(FpFormat::BFLOAT16, schemes, 0.5, 1);
        cfg2.seed = 0;
        let mut eb = GdEngine::new(cfg2, &p, &x0);
        let float_lane = bench("gd_step quad diag n=1000 bf16", 1000, || {
            eb.step();
        });
        let s = report_speedup(&float_lane, &fixed_lane);
        speedups.push(("gd_step_bf16_vs_q3.8".into(), s));
        results.push(fixed_lane);
        results.push(float_lane);
    }

    println!("-- ACCEPTANCE: binary8 MLR rounded gradient, scalar-ref vs kernels --");
    {
        let data = synth::generate(1000, 14, 3);
        let p = Mlr::new(data, 10);
        let mut rngx = Rng::new(9);
        let x0: Vec<f64> = (0..p.dim()).map(|_| 0.05 * rngx.normal()).collect();
        let mut g = vec![0.0; p.dim()];
        let elems = (1000 * 196 * 10) as u64;
        for (label, lp_acc) in [("chop", false), ("absorption", true)] {
            let mut c_ref = LpCtx::new(FpFormat::BINARY8, Scheme::sr(), Rng::new(0));
            let r_ref = bench(&format!("mlr grad b8 SR scalar-ref ({label})"), elems, || {
                p.gradient_reference(&x0, &mut c_ref, &mut g, lp_acc);
            });
            let mut c_new = LpCtx::new(FpFormat::BINARY8, Scheme::sr(), Rng::new(0));
            let r_new = bench(&format!("mlr grad b8 SR kernels    ({label})"), elems, || {
                if lp_acc {
                    p.gradient_per_op(&x0, &mut c_new, &mut g);
                } else {
                    p.gradient_rounded(&x0, &mut c_new, &mut g);
                }
            });
            let s = report_speedup(&r_ref, &r_new);
            println!(
                "acceptance ({label}): {s:.2}x vs pre-PR scalar path (target >= 3.0x) -> {}",
                if s >= 3.0 { "PASS" } else { "BELOW TARGET" }
            );
            speedups.push((format!("mlr_b8_sr_{label}_scalar_vs_kernel"), s));
            results.push(r_ref);
            results.push(r_new);
        }
    }

    println!("-- ACCEPTANCE: 16-seed SR repetition sweep, dense quad n=256 x 10 steps --");
    {
        // Baseline: 16 sequential scalar-engine runs on forced-scalar
        // kernels (the pre-PR repetition loop). Fast path: one
        // run_lane_batch call at L=16 under the runtime-detected SIMD
        // backend. Both sides are timed by this run — never projected.
        let (p, x0, t) = Quadratic::setting2(256, 0);
        let cfg = GdConfig::new(FpFormat::BINARY8, schemes, t, 10);
        let roots: Vec<Rng> = (0..16u64).map(|l| Rng::new(1000 + l)).collect();
        let elems = 16u64 * 10 * 256 * 256;
        // Bit-identity gate first: the lane batch under SIMD must match
        // the scalar engines record for record before timing is trusted.
        {
            set_backend(SimdChoice::Scalar);
            let seq: Vec<_> = roots
                .iter()
                .map(|root| {
                    let mut c = cfg.clone();
                    c.rng = Some(root.clone());
                    GdEngine::new(c, &p, &x0).run(None)
                })
                .collect();
            set_backend(SimdChoice::Auto);
            let batched = run_lane_batch(&cfg, &p, &x0, &roots, None);
            for (a, b) in seq.iter().zip(&batched) {
                assert_eq!(a.records.len(), b.records.len());
                for (ra, rb) in a.records.iter().zip(&b.records) {
                    assert_eq!(
                        ra.f.to_bits(),
                        rb.f.to_bits(),
                        "lane batch diverged from scalar engines"
                    );
                }
            }
        }
        set_backend(SimdChoice::Scalar);
        let base = bench("gd 16 seeds sequential scalar engines", elems, || {
            for root in &roots {
                let mut c = cfg.clone();
                c.rng = Some(root.clone());
                let mut e = GdEngine::new(c, &p, &x0);
                std::hint::black_box(e.run(None));
            }
        });
        set_backend(SimdChoice::Auto);
        let fast =
            bench(&format!("gd 16 seeds lane batch L=16 ({})", backend_label()), elems, || {
                std::hint::black_box(run_lane_batch(&cfg, &p, &x0, &roots, None));
            });
        let s = report_speedup(&base, &fast);
        println!(
            "acceptance: {s:.2}x SIMD+lanes vs sequential scalar (target >= 4.0x) -> {}",
            if s >= 4.0 { "PASS" } else { "BELOW TARGET" }
        );
        speedups.push(("gd_b8_sr_16seeds_scalar_seq_vs_simd_lanes".into(), s));
        results.push(base);
        results.push(fast);
        set_backend(SimdChoice::Auto);
    }

    println!("-- ablation: sigma1 model (dense quad n=300) --");
    {
        let (p, x0, _) = Quadratic::setting2(300, 0);
        let mut g = vec![0.0; 300];
        let mut ctx = LpCtx::new(FpFormat::BFLOAT16, Scheme::sr(), Rng::new(0));
        results.push(bench("gradient round-after-op (chop-style)", 300 * 300, || {
            p.gradient_rounded(&x0, &mut ctx, &mut g);
        }));
        results.push(bench("gradient strict per-op", 300 * 300, || {
            p.gradient_per_op(&x0, &mut ctx, &mut g);
        }));
        results.push(bench("gradient exact (f64)", 300 * 300, || {
            p.gradient_exact(&x0, &mut g);
        }));
    }

    println!("-- ablation: GradModel end-to-end (MLR 1000x196, 1 epoch) --");
    {
        let data = synth::generate(1000, 14, 2);
        let p = Mlr::new(data, 10);
        let x0 = vec![0.0; p.dim()];
        for (name, gm) in [
            ("RoundAfterOp", GradModel::RoundAfterOp),
            ("Exact", GradModel::Exact),
        ] {
            let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes, 0.5, 1);
            cfg.grad_model = gm;
            let mut e = GdEngine::new(cfg, &p, &x0);
            results.push(bench(&format!("mlr epoch grad_model={name}"), 1000 * 196 * 10, || {
                e.step();
            }));
        }
    }

    write_bench_json("gd_step", &results, &speedups).expect("writing BENCH_gd_step.json");
}
