// Bench: full GD-step cost per problem class, plus the sigma1-model
// ablation (chop-style round-after-op vs strict per-op rounding).

include!("harness.rs");

use lpgd::data::synth;
use lpgd::fp::{FpFormat, LpCtx, Rng, Rounding};
use lpgd::gd::engine::{GdConfig, GdEngine, GradModel, StepSchemes};
use lpgd::problems::{Mlr, Problem, Quadratic, TwoLayerNn};

fn main() {
    let schemes = StepSchemes::uniform(Rounding::Sr);

    println!("-- quadratic Setting I (diag, n=1000): one GD step --");
    {
        let (p, x0, t) = Quadratic::setting1(1000);
        let mut cfg = GdConfig::new(FpFormat::BFLOAT16, schemes, t, 1);
        cfg.seed = 0;
        let mut e = GdEngine::new(cfg, &p, &x0);
        bench("gd_step quad diag n=1000", 1000, || {
            e.step();
        });
    }

    println!("-- quadratic Setting II (dense, n=500): one GD step --");
    {
        let (p, x0, t) = Quadratic::setting2(500, 0);
        let mut cfg = GdConfig::new(FpFormat::BFLOAT16, schemes, t, 1);
        cfg.seed = 0;
        let mut e = GdEngine::new(cfg, &p, &x0);
        bench("gd_step quad dense n=500", 500 * 500, || {
            e.step();
        });
    }

    println!("-- MLR full-batch epoch (4000x196, C=10) --");
    {
        let data = synth::generate(4000, 14, 0);
        let p = Mlr::new(data, 10);
        let x0 = vec![0.0; p.dim()];
        let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes, 0.5, 1);
        cfg.seed = 0;
        let mut e = GdEngine::new(cfg, &p, &x0);
        bench("gd_step mlr 4000x196", 4000 * 196 * 10, || {
            e.step();
        });
    }

    println!("-- NN epoch (1200x196, H=100) --");
    {
        let data = synth::generate(6000, 14, 1).filter_classes(&[3, 8]);
        let p = TwoLayerNn::new(data, 100);
        let x0 = p.init_params(0);
        let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes, 0.09375, 1);
        cfg.seed = 0;
        let mut e = GdEngine::new(cfg, &p, &x0);
        bench("gd_step nn 1200x196 h=100", 1200 * 196 * 100, || {
            e.step();
        });
    }

    println!("-- ablation: sigma1 model (dense quad n=300) --");
    {
        let (p, x0, _) = Quadratic::setting2(300, 0);
        let mut g = vec![0.0; 300];
        let mut ctx = LpCtx::new(FpFormat::BFLOAT16, Rounding::Sr, Rng::new(0));
        bench("gradient round-after-op (chop-style)", 300 * 300, || {
            p.gradient_rounded(&x0, &mut ctx, &mut g);
        });
        bench("gradient strict per-op", 300 * 300, || {
            p.gradient_per_op(&x0, &mut ctx, &mut g);
        });
        bench("gradient exact (f64)", 300 * 300, || {
            p.gradient_exact(&x0, &mut g);
        });
    }

    println!("-- ablation: GradModel end-to-end (MLR 1000x196, 1 epoch) --");
    {
        let data = synth::generate(1000, 14, 2);
        let p = Mlr::new(data, 10);
        let x0 = vec![0.0; p.dim()];
        for (name, gm) in [
            ("RoundAfterOp", GradModel::RoundAfterOp),
            ("Exact", GradModel::Exact),
        ] {
            let mut cfg = GdConfig::new(FpFormat::BINARY8, schemes, 0.5, 1);
            cfg.grad_model = gm;
            let mut e = GdEngine::new(cfg, &p, &x0);
            bench(&format!("mlr epoch grad_model={name}"), 1000 * 196 * 10, || {
                e.step();
            });
        }
    }
}
