// Bench: the rounding hot path (Layer-3 side of the paper's kernel):
// fused slice kernels vs the scalar reference path, per scheme and format,
// plus the few-random-bits knob ablation. Emits BENCH_rounding.json.

include!("harness.rs");

use lpgd::fp::{
    avx2_active, backend_label, round, round_slice, round_slice_with, set_backend, FixedPoint,
    FpFormat, Rng, RoundPlan, Rounding, SimdChoice,
};

fn main() {
    warn_if_hand_projected("rounding");
    let fmt = FpFormat::BINARY8;
    let n = 1 << 16;
    let mut rng = Rng::new(0);
    let xs: Vec<f64> = (0..n).map(|_| rng.normal() * 3.0).collect();
    let vs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut results: Vec<BenchResult> = Vec::new();
    let mut speedups: Vec<(String, f64)> = Vec::new();

    println!("-- fused slice rounding, binary8, {n} elements per iter --");
    for mode in [
        Rounding::RoundNearestEven,
        Rounding::RoundDown,
        Rounding::Sr,
        Rounding::SrEps(0.25),
        Rounding::SignedSrEps(0.25),
    ] {
        let mut r = Rng::new(1);
        let mut buf = xs.clone();
        results.push(bench(&format!("round_slice {}", mode.label()), n as u64, || {
            buf.copy_from_slice(&xs);
            round_slice(&fmt, mode, &mut buf, &mut r);
        }));
    }

    println!("-- scalar reference vs fused slice (SR) --");
    {
        let mut r = Rng::new(6);
        let mut buf = xs.clone();
        let plan = RoundPlan::new(fmt);
        let scalar = bench("scalar round loop SR", n as u64, || {
            buf.copy_from_slice(&xs);
            for v in buf.iter_mut() {
                *v = plan.round(Rounding::Sr, *v, &mut r);
            }
        });
        let mut r2 = Rng::new(6);
        let mut buf2 = xs.clone();
        let fused = bench("fused round_slice SR", n as u64, || {
            buf2.copy_from_slice(&xs);
            plan.round_slice(Rounding::Sr, &mut buf2, &mut r2);
        });
        let s = report_speedup(&scalar, &fused);
        speedups.push(("sr_scalar_vs_slice".into(), s));
        results.push(scalar);
        results.push(fused);
    }

    println!("-- SIMD dispatch: forced-scalar vs runtime-detected (binary8 slice) --");
    {
        let plan = RoundPlan::new(fmt);
        // Bit-identity gate before any timing is trusted: both backends
        // must produce identical outputs AND consume the stream
        // identically (docs/performance.md).
        {
            let (mut ra, mut rb) = (Rng::new(77), Rng::new(77));
            let mut a = xs.clone();
            let mut b = xs.clone();
            set_backend(SimdChoice::Scalar);
            plan.round_slice(Rounding::Sr, &mut a, &mut ra);
            set_backend(SimdChoice::Auto);
            plan.round_slice(Rounding::Sr, &mut b, &mut rb);
            assert_eq!(a, b, "SIMD backend diverged bitwise from the scalar kernel");
            assert_eq!(ra.next_u64(), rb.next_u64(), "SIMD backend desynced the bit stream");
        }
        for (mode, tag) in [
            (Rounding::Sr, "SR"),
            (Rounding::RoundNearestEven, "RN"),
            (Rounding::SrEps(0.25), "SR_eps(0.25)"),
        ] {
            set_backend(SimdChoice::Scalar);
            let mut r = Rng::new(31);
            let mut buf = xs.clone();
            let scalar = bench(&format!("round_slice {tag} forced-scalar"), n as u64, || {
                buf.copy_from_slice(&xs);
                plan.round_slice(mode, &mut buf, &mut r);
            });
            set_backend(SimdChoice::Auto);
            let mut r2 = Rng::new(31);
            let mut buf2 = xs.clone();
            let auto =
                bench(&format!("round_slice {tag} auto ({})", backend_label()), n as u64, || {
                    buf2.copy_from_slice(&xs);
                    plan.round_slice(mode, &mut buf2, &mut r2);
                });
            if avx2_active() {
                let s = report_speedup(&scalar, &auto);
                speedups.push((format!("slice_scalar_vs_simd {tag}"), s));
            } else {
                println!("note: AVX2 unavailable here; both lanes ran the scalar kernel");
            }
            results.push(scalar);
            results.push(auto);
        }
        set_backend(SimdChoice::Auto);
    }

    println!("-- open-scheme dispatch overhead (Scheme handle vs enum, SR slice) --");
    {
        let plan = RoundPlan::new(fmt);
        let scheme = Rounding::Sr.scheme();
        // Built-in Scheme handles must resolve to the same fused kernel:
        // bit-identical outputs from identical stream states.
        {
            let (mut ra, mut rb) = (Rng::new(99), Rng::new(99));
            let mut a = xs.clone();
            let mut b = xs.clone();
            plan.round_slice(Rounding::Sr, &mut a, &mut ra);
            plan.round_slice_scheme(scheme, &mut b, &mut rb);
            assert_eq!(a, b, "Scheme dispatch diverged from the enum kernel");
        }
        let mut r = Rng::new(6);
        let mut buf = xs.clone();
        let enum_path = bench("round_slice enum SR", n as u64, || {
            buf.copy_from_slice(&xs);
            plan.round_slice(Rounding::Sr, &mut buf, &mut r);
        });
        let mut r2 = Rng::new(6);
        let mut buf2 = xs.clone();
        let scheme_path = bench("round_slice_scheme SR", n as u64, || {
            buf2.copy_from_slice(&xs);
            plan.round_slice_scheme(scheme, &mut buf2, &mut r2);
        });
        let s = report_speedup(&enum_path, &scheme_path);
        speedups.push(("sr_enum_vs_scheme_dispatch".into(), s));
        results.push(enum_path);
        results.push(scheme_path);
    }

    println!("-- few-random-bits knob (SR slice, bits per rounding) --");
    for bits in [8u32, 16, 32, 53] {
        let plan = RoundPlan::new(fmt).with_sr_bits(bits);
        let mut r = Rng::new(7);
        let mut buf = xs.clone();
        results.push(bench(&format!("round_slice SR sr_bits={bits}"), n as u64, || {
            buf.copy_from_slice(&xs);
            plan.round_slice(Rounding::Sr, &mut buf, &mut r);
        }));
    }

    println!("-- steered signed-SR_eps (per-element v) --");
    {
        let mut r = Rng::new(2);
        let mut buf = xs.clone();
        results.push(bench("round_slice_with signed-SR_eps(0.25)", n as u64, || {
            buf.copy_from_slice(&xs);
            round_slice_with(&fmt, Rounding::SignedSrEps(0.25), &mut buf, &vs, &mut r);
        }));
    }

    println!("-- bfloat16 vs binary8 (same scheme) --");
    for fmt2 in [FpFormat::BINARY8, FpFormat::BFLOAT16, FpFormat::BINARY16] {
        let mut r = Rng::new(3);
        let mut buf = xs.clone();
        results.push(bench(&format!("round_slice SR {}", fmt2.name()), n as u64, || {
            buf.copy_from_slice(&xs);
            round_slice(&fmt2, Rounding::Sr, &mut buf, &mut r);
        }));
    }

    println!("-- ablation: representable fast-path (values already in F) --");
    {
        let mut r = Rng::new(4);
        let mut inf_vals = xs.clone();
        round_slice(&fmt, Rounding::RoundNearestEven, &mut inf_vals, &mut r);
        let mut buf = inf_vals.clone();
        results.push(bench("round_slice SR on representable input", n as u64, || {
            buf.copy_from_slice(&inf_vals);
            round_slice(&fmt, Rounding::Sr, &mut buf, &mut r);
        }));
    }

    println!("-- fixed-point lane: integer-quantization kernel (Q3.8) --");
    {
        let fx = FixedPoint::q(3, 8);
        // Scale the inputs into the Q3.8 range so the fast path dominates,
        // mirroring the float lanes' in-range mix.
        let mut gen = Rng::new(12);
        let fxs: Vec<f64> = (0..n).map(|_| gen.normal() * 2.0).collect();
        for mode in [Rounding::RoundNearestEven, Rounding::Sr, Rounding::SignedSrEps(0.25)] {
            let plan = RoundPlan::new(fx);
            let mut r = Rng::new(8);
            let mut buf = fxs.clone();
            results.push(bench(&format!("round_slice q3.8 {}", mode.label()), n as u64, || {
                buf.copy_from_slice(&fxs);
                plan.round_slice_with(mode, &mut buf, &fxs, &mut r);
            }));
        }
        // Head-to-head: the same SR law through the float bit-pattern
        // kernel (binary8) vs the fixed integer-quantization kernel.
        let planf = RoundPlan::new(fmt);
        let mut rf = Rng::new(8);
        let mut bf = fxs.clone();
        let float_lane = bench("round_slice SR binary8 (same inputs)", n as u64, || {
            bf.copy_from_slice(&fxs);
            planf.round_slice(Rounding::Sr, &mut bf, &mut rf);
        });
        let planq = RoundPlan::new(fx);
        let mut rq = Rng::new(8);
        let mut bq = fxs.clone();
        let fixed_lane = bench("round_slice SR q3.8    (same inputs)", n as u64, || {
            bq.copy_from_slice(&fxs);
            planq.round_slice(Rounding::Sr, &mut bq, &mut rq);
        });
        let s = report_speedup(&float_lane, &fixed_lane);
        speedups.push(("sr_float_bitkernel_vs_fixed_quant".into(), s));
        results.push(float_lane);
        results.push(fixed_lane);
    }

    println!("-- single value micro (ns/round) --");
    {
        let mut r = Rng::new(5);
        let mut acc = 0.0;
        results.push(bench("round scalar SR", 1, || {
            acc += round(&fmt, Rounding::Sr, 1.1, &mut r);
        }));
        std::hint::black_box(acc);
    }

    write_bench_json("rounding", &results, &speedups).expect("writing BENCH_rounding.json");
}
