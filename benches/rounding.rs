//! Bench: the rounding hot path (Layer-3 side of the paper's kernel).
//! Regenerates the per-scheme cost table in EXPERIMENTS.md §Perf.

include!("harness.rs");

use lpgd::fp::{round, round_slice, round_slice_with, FpFormat, Rng, Rounding};

fn main() {
    let fmt = FpFormat::BINARY8;
    let n = 1 << 16;
    let mut rng = Rng::new(0);
    let xs: Vec<f64> = (0..n).map(|_| rng.normal() * 3.0).collect();
    let vs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

    println!("-- scalar rounding, binary8, {n} elements per iter --");
    for mode in [
        Rounding::RoundNearestEven,
        Rounding::RoundDown,
        Rounding::Sr,
        Rounding::SrEps(0.25),
        Rounding::SignedSrEps(0.25),
    ] {
        let mut r = Rng::new(1);
        let mut buf = xs.clone();
        bench(&format!("round_slice {}", mode.label()), n as u64, || {
            buf.copy_from_slice(&xs);
            round_slice(&fmt, mode, &mut buf, &mut r);
        });
    }

    println!("-- steered signed-SR_eps (per-element v) --");
    {
        let mut r = Rng::new(2);
        let mut buf = xs.clone();
        bench("round_slice_with signed-SR_eps(0.25)", n as u64, || {
            buf.copy_from_slice(&xs);
            round_slice_with(&fmt, Rounding::SignedSrEps(0.25), &mut buf, &vs, &mut r);
        });
    }

    println!("-- bfloat16 vs binary8 (same scheme) --");
    for fmt2 in [FpFormat::BINARY8, FpFormat::BFLOAT16, FpFormat::BINARY16] {
        let mut r = Rng::new(3);
        let mut buf = xs.clone();
        bench(&format!("round_slice SR {}", fmt2.name()), n as u64, || {
            buf.copy_from_slice(&xs);
            round_slice(&fmt2, Rounding::Sr, &mut buf, &mut r);
        });
    }

    println!("-- ablation: representable fast-path (values already in F) --");
    {
        let mut r = Rng::new(4);
        let mut inf_vals = xs.clone();
        round_slice(&fmt, Rounding::RoundNearestEven, &mut inf_vals, &mut r);
        let mut buf = inf_vals.clone();
        bench("round_slice SR on representable input", n as u64, || {
            buf.copy_from_slice(&inf_vals);
            round_slice(&fmt, Rounding::Sr, &mut buf, &mut r);
        });
    }

    println!("-- single value micro (ns/round) --");
    {
        let mut r = Rng::new(5);
        let mut acc = 0.0;
        bench("round scalar SR", 1, || {
            acc += round(&fmt, Rounding::Sr, 1.1, &mut r);
        });
        std::hint::black_box(acc);
    }
}
