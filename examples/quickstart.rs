//! Quickstart: the library in 60 lines.
//!
//! 1. Round values into binary8 with each scheme and see the bias.
//! 2. Run low-precision GD on a tiny quadratic and watch RN stagnate while
//!    SR and signed-SRε keep converging (the paper's core story).
//!
//! Run: `cargo run --release --example quickstart`

use lpgd::fp::{expected_round, FpFormat, Rng, Rounding, Scheme};
use lpgd::gd::engine::{GdConfig, GdEngine, PolicyMap};
use lpgd::problems::Quadratic;

fn main() {
    let fmt = FpFormat::BINARY8; // E5M2: u = 2^-3
    println!("binary8: u={}, x_max={}", fmt.unit_roundoff(), fmt.x_max());

    // --- 1. rounding one value -------------------------------------------
    let x = 1.1; // sits between 1.0 and 1.25 in binary8
    let (lo, hi) = fmt.floor_ceil(x);
    println!("\nx = {x} has binary8 neighbors [{lo}, {hi}]");
    for mode in [
        Rounding::RoundNearestEven,
        Rounding::Sr,
        Rounding::SrEps(0.25),
        Rounding::SignedSrEps(0.25), // steered by v = x here
    ] {
        let e = expected_round(&fmt, mode, x, x);
        println!("  {:<22} E[fl(x)] = {e:<8} bias = {:+.4}", mode.label(), e - x);
    }

    // --- 2. GD in binary8: RN stagnates, stochastic schemes do not -------
    // f(x) = (x - 1024)^2, start far away at x0 = 1, t = 0.05 (paper 3.2).
    let p = Quadratic::diagonal(vec![2.0], vec![1024.0]);
    println!("\nGD on f(x)=(x-1024)^2 in binary8, 120 steps from x0=1:");
    for (name, schemes) in [
        ("RN", PolicyMap::uniform(Scheme::rn())),
        ("SR", PolicyMap::uniform(Scheme::sr())),
        (
            "SR + signed-SR_eps(0.25) for (8c)",
            PolicyMap::sites(Scheme::sr(), Scheme::sr(), Scheme::signed_sr_eps(0.25)),
        ),
    ] {
        let mut cfg = GdConfig::new(fmt, schemes, 0.05, 120);
        cfg.seed = 7;
        let mut engine = GdEngine::new(cfg, &p, &[1.0]);
        let trace = engine.run(None);
        let onset = trace
            .stagnation_onset()
            .map(|k| format!("stagnated at k={k}"))
            .unwrap_or_else(|| "no stagnation".into());
        println!(
            "  {name:<34} final x = {:<8} f = {:<12.4} {onset}",
            engine.x[0],
            trace.final_f()
        );
    }

    // --- 3. a taste of the RNG-stream discipline -------------------------
    let root = Rng::new(42);
    let mut s1 = root.fork("demo", 0);
    let mut s2 = root.fork("demo", 1);
    println!("\nindependent streams: {:.4} vs {:.4}", s1.uniform(), s2.uniform());
    println!("\nNext: `cargo run --release --example quadratic_convergence`");
}
