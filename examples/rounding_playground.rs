//! Rounding playground: print Figure-1-style expectation curves and verify
//! the paper's Lemma 1 bound numerically for any format from the CLI.
//!
//! Run: `cargo run --release --example rounding_playground -- [bfloat16]`

use lpgd::fp::{expected_round, FpFormat, Rounding};

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "binary8".into());
    let fmt = FpFormat::by_name(&name).expect("unknown format");
    let u = fmt.unit_roundoff();
    println!("format {name}: u = {u}");

    // E[fl(y)] across the gap (1, su(1)) — the paper's Figure 1 content.
    let lo = 1.0;
    let hi = fmt.successor(1.0);
    println!("\n y (in ({lo}, {hi}))   RN        SR        SR_eps(.25) signed(.25, v=+1)");
    for i in 1..10 {
        let y = lo + (hi - lo) * i as f64 / 10.0;
        println!(
            " {y:<18.6} {:<9.5} {:<9.5} {:<11.5} {:<9.5}",
            expected_round(&fmt, Rounding::RoundNearestEven, y, y),
            expected_round(&fmt, Rounding::Sr, y, y),
            expected_round(&fmt, Rounding::SrEps(0.25), y, y),
            expected_round(&fmt, Rounding::SignedSrEps(0.25), y, 1.0),
        );
    }

    // Lemma 1: 0 <= E[delta^{SR_eps}] <= 2*eps*u over a wide magnitude sweep.
    let eps = 0.3;
    let mut worst: f64 = 0.0;
    let mut x = 1.7e-3;
    while x < 1e3 {
        for s in [x, -x] {
            let e = expected_round(&fmt, Rounding::SrEps(eps), s, s);
            let rel: f64 = (e - s) / s;
            assert!(rel >= -1e-14, "negative relative bias at {s}");
            worst = worst.max(rel);
        }
        x *= 1.37;
    }
    println!("\nLemma 1 check: max E[delta] = {worst:.5e} <= 2*eps*u = {:.5e}  OK", 2.0 * eps * u);
}
