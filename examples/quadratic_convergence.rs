//! Figure-3 style study on the quadratic Setting II: binary32 baseline vs
//! bfloat16 with SR and with signed-SR_eps(0.4), against the Theorem-2 bound.
//!
//! Run: `cargo run --release --example quadratic_convergence -- [n] [steps]`

use lpgd::fp::{FpFormat, Scheme};
use lpgd::gd::engine::{GdConfig, GdEngine, PolicyMap};
use lpgd::gd::theory;
use lpgd::problems::{Problem, Quadratic};
use lpgd::util::table::sparkline;

fn main() {
    let mut args = std::env::args().skip(1);
    let n: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(300);
    let steps: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(1500);
    let (p, x0, t) = Quadratic::setting2(n, 0);
    let lip = p.lipschitz().unwrap();
    println!("Setting II: dense A in R^{n}x{n}, spectrum 1..{n}, t = 1/L = {t}");

    let run = |fmt: FpFormat, schemes: PolicyMap, seed: u64| {
        let mut cfg = GdConfig::new(fmt, schemes, t, steps);
        cfg.seed = seed;
        let mut e = GdEngine::new(cfg, &p, &x0);
        let tr = e.run(None);
        (tr, e.x)
    };

    let (base, _) = run(FpFormat::BINARY32, PolicyMap::uniform(Scheme::rn()), 0);
    let (sr, x_sr) = run(FpFormat::BFLOAT16, PolicyMap::uniform(Scheme::sr()), 1);
    let (sg, x_sg) = run(
        FpFormat::BFLOAT16,
        PolicyMap::sites(Scheme::sr(), Scheme::sr(), Scheme::signed_sr_eps(0.4)),
        1,
    );

    let dist0 = {
        let d = lpgd::fp::linalg::exact::sub(&x0, p.optimum().unwrap());
        lpgd::fp::linalg::exact::norm2(&d)
    };
    let logs = |v: &[f64]| -> Vec<f64> { v.iter().map(|x| x.max(1e-30).log10()).collect() };
    println!("\nlog10 f(x_k) over {steps} iterations:");
    println!("  thm2 bound    {}", sparkline(&logs(&(0..steps).map(|k| theory::theorem2_bound(lip, t, k, dist0)).collect::<Vec<_>>()), 60));
    println!("  binary32 RN   {}", sparkline(&logs(&base.objective_series()), 60));
    println!("  bf16 SR       {}", sparkline(&logs(&sr.objective_series()), 60));
    println!("  bf16 signed   {}", sparkline(&logs(&sg.objective_series()), 60));
    println!(
        "\nfinal f: binary32={:.3e}  SR={:.3e}  signed-SR_eps(0.4)={:.3e}",
        base.final_f(),
        sr.final_f(),
        sg.final_f()
    );
    let rel = |x: &[f64]| {
        let d = lpgd::fp::linalg::exact::sub(x, p.optimum().unwrap());
        lpgd::fp::linalg::exact::norm2(&d) / lpgd::fp::linalg::exact::norm2(p.optimum().unwrap())
    };
    println!(
        "relative error ||x-x*||/||x*||: SR={:.3}  signed={:.3}   (paper fig3b: 1.50 vs 0.12)",
        rel(&x_sr),
        rel(&x_sg)
    );
}
