//! Two-layer NN on the 3-vs-8 task (paper §5.3), pure-Rust engine path:
//! compares RN / SR / SR_eps / signed-SR_eps at binary8 in one run and
//! prints the epochs-to-target speedup (the paper's ~2x claim).
//!
//! Run: `cargo run --release --example train_nn -- [epochs]`

use lpgd::data::load_or_synth;
use lpgd::fp::{FpFormat, Scheme};
use lpgd::gd::engine::{GdConfig, GdEngine, PolicyMap};
use lpgd::problems::TwoLayerNn;
use lpgd::util::stats::first_at_or_below;
use lpgd::util::table::sparkline;

fn main() {
    let epochs: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(50);
    let splits = load_or_synth(None, 3000, 1000, 14, 77);
    let train = splits.train.filter_classes(&[3, 8]);
    let test = splits.test.filter_classes(&[3, 8]);
    println!("3-vs-8: {} train / {} test", train.len(), test.len());
    let nn = TwoLayerNn::new(train, 100);
    let x0 = nn.init_params(0);
    let t = 0.09375; // paper §5.3

    let curve = |fmt: FpFormat, schemes: PolicyMap| -> Vec<f64> {
        let mut cfg = GdConfig::new(fmt, schemes, t, epochs);
        cfg.seed = 3;
        let mut e = GdEngine::new(cfg, &nn, &x0);
        let metric = |x: &[f64]| nn.test_error(x, &test);
        e.run(Some(&metric)).metric_series()
    };

    let sr = Scheme::sr();
    let runs = [
        ("binary32 (baseline)", FpFormat::BINARY32, PolicyMap::uniform(Scheme::rn())),
        ("binary8 RN", FpFormat::BINARY8, PolicyMap::uniform(Scheme::rn())),
        ("binary8 SR", FpFormat::BINARY8, PolicyMap::uniform(sr)),
        ("binary8 SR|signed(0.1)", FpFormat::BINARY8,
         PolicyMap::sites(sr, sr, Scheme::signed_sr_eps(0.1))),
    ];
    let mut curves = Vec::new();
    for (name, fmt, sch) in runs {
        let c = curve(fmt, sch);
        println!("{name:<24} final err {:.3}  {}", c.last().unwrap(), sparkline(&c, 50));
        curves.push((name, c));
    }
    let target = *curves[0].1.last().unwrap();
    println!("\nepochs to reach the baseline {epochs}-epoch error ({target:.3}):");
    for (name, c) in &curves[1..] {
        match first_at_or_below(c, target) {
            Some(k) => println!("  {name:<24} {k}"),
            None => println!("  {name:<24} never (stagnated or too slow)"),
        }
    }
}
