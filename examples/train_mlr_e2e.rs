//! END-TO-END DRIVER: trains the paper's multinomial logistic regression
//! with binary8 rounded GD **through the fused kernel layer** — the rounded
//! GEMM logits, the fused softmax-row kernel, the slice-rounded gradient
//! accumulators (`fp::kernels`), and the batched few-random-bits SR stream
//! — configured through the [`RunBuilder`] front door and the open scheme
//! registry, so any registered scheme name works on the command line.
//! Doubles as a smoke benchmark: it reports end-to-end training throughput
//! (epochs/sec) and the (8a) rounding throughput (rounding ops/sec).
//!
//! Run: `cargo run --release --example train_mlr_e2e -- [epochs] [scheme]`
//!   scheme ∈ any registered spec: rn | rd | ru | rz | sr | sr_eps:0.2 |
//!   signed:0.1 | ...   (default sr; `lpgd --help` lists them all)
//!
//! (The AOT-compiled PJRT variant of this driver lives behind the
//! non-default `pjrt` feature — see `benches/runtime_pjrt.rs` and
//! `rust/src/runtime/`; this example exercises the native Rust hot path
//! that the perf work of docs/performance.md targets.)

use lpgd::data::load_or_synth;
use lpgd::fp::{FpFormat, SchemeRegistry};
use lpgd::gd::RunBuilder;
use lpgd::problems::{Mlr, Problem};
use lpgd::util::table::sparkline;

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let epochs: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(30);
    // Registry lookup: unknown specs exit with the registered-scheme list.
    let scheme = SchemeRegistry::lookup(&args.next().unwrap_or_else(|| "sr".into()))?;

    let splits = load_or_synth(None, 2048, 512, 14, 42);
    let mlr = Mlr::new(splits.train, 10);
    println!(
        "e2e MLR: {} train / {} test, D={}, C=10, binary8, scheme {}, {} params",
        mlr.data.len(),
        splits.test.len(),
        mlr.data.n_features,
        scheme.label(),
        mlr.dim()
    );

    // The documented front door: builder -> session (chop-style gradient
    // model and zero start are the defaults; see docs/api.md).
    let mut session = RunBuilder::new(&mlr)
        .format(FpFormat::BINARY8)
        .policy(scheme)
        .stepsize(0.5)
        .steps(epochs)
        .seed(0)
        .build()?;

    let mut errs = Vec::with_capacity(epochs);
    let mut train_secs = 0.0f64;
    for _ in 0..epochs {
        let t0 = std::time::Instant::now();
        session.step(); // full-batch epoch: (8a) kernel gradient + (8b)/(8c)
        train_secs += t0.elapsed().as_secs_f64();
        errs.push(mlr.test_error(session.x(), &splits.test));
    }

    let rounds = session.grad_rounding_ops();
    println!(
        "ran {epochs} rounded epochs in {train_secs:.2}s ({:.2} epochs/s, {:.1} ms/epoch)",
        epochs as f64 / train_secs,
        1e3 * train_secs / epochs as f64
    );
    println!(
        "(8a) rounding ops: {rounds} total -> {:.1} Mrounds/s through the kernel layer",
        rounds as f64 / train_secs / 1e6
    );
    println!("test-error curve: {}", sparkline(&errs, 60));
    println!(
        "test error: first epoch {:.3} -> final {:.3}",
        errs.first().unwrap(),
        errs.last().unwrap()
    );
    anyhow::ensure!(
        *errs.last().unwrap() < 0.5,
        "end-to-end training failed to beat chance"
    );
    println!("E2E OK: kernel-layer training pipeline composed");
    Ok(())
}
