//! END-TO-END DRIVER (deliverable (b)/EXPERIMENTS.md): trains the paper's
//! multinomial logistic regression with binary8 rounded GD **through the
//! full three-layer stack**:
//!
//!   Layer 3 (this binary, Rust): data pipeline, uniform-field generation
//!     from PCG streams, epoch loop, metrics;
//!   Layer 2 (AOT JAX): `artifacts/mlr_step.hlo.txt` — forward, backward,
//!     and the (8a)/(8b)/(8c) rounded update in one compiled graph;
//!   Layer 1 (Pallas): the stochastic-rounding quantizer lowered inside it.
//!
//! Python does NOT run here; build artifacts first with `make artifacts`.
//!
//! Run: `cargo run --release --example train_mlr_e2e -- [epochs] [scheme]`
//!   scheme ∈ rn | sr | sr_eps:0.2 | signed:0.1   (default sr)

use lpgd::data::load_or_synth;
use lpgd::fp::{Rng, Rounding};
use lpgd::problems::Mlr;
use lpgd::runtime::{artifacts::mode, Arg, Runtime, MLR_SPEC};
use lpgd::util::table::sparkline;

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let epochs: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(30);
    let scheme = Rounding::parse(&args.next().unwrap_or_else(|| "sr".into()))
        .expect("bad scheme (rn|sr|sr_eps:E|signed:E)");
    let (mode_id, eps) = mode::from_rounding(scheme);

    let spec = MLR_SPEC;
    let n = spec.batch; // 256-sample batches, D=196, C=10 (artifact ABI)
    let splits = load_or_synth(None, 2048, 512, 14, 42);
    let mlr = Mlr::new(splits.train, spec.classes); // exact-eval mirror for metrics
    println!(
        "e2e MLR: {} train / {} test, artifact {} ({} params), scheme {}",
        2048, 512, spec.file, spec.params, scheme.label()
    );

    let mut rt = Runtime::cpu("artifacts")?;
    println!("PJRT platform: {}", rt.platform());

    let mut params = vec![0.0f64; spec.params];
    let root = Rng::new(0);
    let mut uni_rng = root.fork("uniforms", 0);
    let mut errs = Vec::with_capacity(epochs);
    let t_step = 0.5f32;
    let batches = 2048 / n;
    let started = std::time::Instant::now();
    let mut steps = 0u32;

    for _epoch in 0..epochs {
        for b in 0..batches {
            // Marshal the batch (row-major f32) + one-hot labels.
            let mut xb = Vec::with_capacity(n * spec.features);
            let mut yb = vec![0.0f64; n * spec.classes];
            for i in 0..n {
                let row = mlr.data.row(b * n + i);
                xb.extend_from_slice(row);
                yb[i * spec.classes + mlr.data.labels[b * n + i] as usize] = 1.0;
            }
            // Fresh uniform field for the three rounding applications.
            let uni: Vec<f64> = (0..3 * spec.params).map(|_| uni_rng.uniform()).collect();
            let exe = rt.load(spec.file)?;
            let out = exe.run_f32(&[
                Arg::f32_from_f64(&params, &[spec.params as i64]),
                Arg::f32_from_f64(&xb, &[n as i64, spec.features as i64]),
                Arg::f32_from_f64(&yb, &[n as i64, spec.classes as i64]),
                Arg::f32_from_f64(&uni, &[3, spec.params as i64]),
                Arg::ScalarF32(t_step),
                Arg::ScalarF32(eps),
                Arg::I32(vec![mode_id; 3], vec![3]),
            ])?;
            params = out[0].iter().map(|&v| v as f64).collect();
            steps += 1;
        }
        let err = mlr.test_error(&params, &splits.test);
        errs.push(err);
    }
    let dt = started.elapsed().as_secs_f64();
    println!(
        "ran {steps} PJRT train steps in {dt:.2}s ({:.1} steps/s, {:.2} ms/step)",
        steps as f64 / dt,
        1e3 * dt / steps as f64
    );
    println!("test-error curve: {}", sparkline(&errs, 60));
    println!(
        "test error: first epoch {:.3} -> final {:.3}",
        errs.first().unwrap(),
        errs.last().unwrap()
    );
    anyhow::ensure!(
        *errs.last().unwrap() < 0.5,
        "end-to-end training failed to beat chance"
    );
    println!("E2E OK: all three layers composed");
    Ok(())
}
